//! Structural PPA assembly for every Table-I MAC design point.
//!
//! Each MAC is described as a pipeline of datapath blocks; each block
//! carries its gate counts, its own logic depth, and the logic depth of its
//! *input arrival* — the latter drives the glitch multiplier: blocks fed by
//! deep, skewed logic (the accumulate CPA of a conventional MAC sits behind
//! DRU → CEL → product CPA) see far more spurious transitions than blocks
//! fed from registers (the TCD-MAC's CEL reads the ORU/CBU registers
//! directly). This is the physically-grounded mechanism behind the paper's
//! energy win, and it emerges here rather than being hard-coded.
//!
//! Switching activity is *measured*, not assumed: [`measure_activity`] runs
//! the paper's 20K-cycle random-stimulus protocol on the functional models
//! and normalizes monitored-bus toggles into an activity factor.

use super::{MacKind, ACC_WIDTH, PROD_WIDTH};
use crate::bitsim::adder::{Adder, AdderKind};
use crate::bitsim::multiplier::{MultKind, PartialProducts, OP_WIDTH};
use crate::bitsim::netlist::{Depth, GateCounts};
use crate::ppa::{PpaReport, TechParams, VoltageDomain};
use crate::util::SplitMix64;

/// One pipeline stage of a MAC datapath.
#[derive(Debug, Clone)]
pub struct DatapathBlock {
    pub name: &'static str,
    pub gates: GateCounts,
    /// The block's own logic depth, τ.
    pub depth: Depth,
    /// Arrival depth of its inputs (0 = register outputs), τ.
    pub input_depth: Depth,
    /// Fraction of cycles the block switches (1.0 except the TCD-MAC's
    /// deferred PCPA, which fires once per stream).
    pub duty: f64,
    /// Whether the block's depth is on the per-cycle critical path
    /// (the TCD-MAC's PCPA is not: its latency hides in the extra
    /// carry-propagation cycle, Fig. 2).
    pub on_cycle_path: bool,
}

/// A fully assembled structural model of one MAC design point.
#[derive(Debug, Clone)]
pub struct MacPpaModel {
    pub kind: MacKind,
    pub blocks: Vec<DatapathBlock>,
}

/// Synthesis timing-pressure upsizing: designs synthesized at max frequency
/// with deeper critical paths receive more gate upsizing / buffering.
/// Linear in depth with a calibrated slope.
fn upsize_factor(cycle_depth: Depth) -> f64 {
    1.0 + 0.012 * cycle_depth
}

/// Glitch multiplier as a function of input-arrival depth: spurious
/// transitions accumulate roughly linearly with arrival-time skew.
fn glitch_factor(input_depth: Depth) -> f64 {
    1.0 + 0.20 * input_depth
}

/// Default duty of the TCD PCPA in per-cycle energy: one firing per stream;
/// Table-I characterization uses the paper's stream protocol (~20 steps
/// between resolutions is conservative for MLP layers with I ≥ 100).
const TCD_PCPA_DUTY: f64 = 0.05;

/// CEL gate counts from the bit population: each 3:2 compression retires
/// one bit, so FA count ≈ input bits − output bits (Dadda bound), plus a
/// row of half-adders for the 2-high remainder columns.
///
/// `extra_bits` (the TCD-MAC's re-injected ORU/CBU planes) are charged at
/// half-adder cost: the paper routes the CB bits into *incomplete*
/// C_HW(m:n) compressors specifically so the tree does not grow
/// (§III-A) — the residual cost is the widened upper-region columns.
fn cel_gates(pp_bits: u64, extra_bits: u64, out_width: u32) -> GateCounts {
    let bits_out = 2 * out_width as u64;
    GateCounts {
        full_adder: pp_bits.saturating_sub(bits_out),
        half_adder: out_width as u64 / 2 + extra_bits / 2,
        ..Default::default()
    }
}

/// Total partial-product bits for a generator (staggered row widths).
fn pp_bits(kind: MultKind) -> u64 {
    let rw = (OP_WIDTH + 1) as u64; // row datapath width before shift
    match kind {
        MultKind::Simple => 16 * rw,
        MultKind::BoothRadix2 => 16 * rw,
        MultKind::BoothRadix4 => 8 * (rw + 1),
        MultKind::BoothRadix8 => 6 * (rw + 2),
    }
}

impl MacPpaModel {
    /// Assemble the structural model for a design point.
    pub fn assemble(kind: MacKind) -> Self {
        let blocks = match kind {
            MacKind::Conv(m, a) => Self::conv_blocks(m, a),
            MacKind::Tcd => Self::tcd_blocks(),
        };
        Self { kind, blocks }
    }

    fn conv_blocks(m: MultKind, a: AdderKind) -> Vec<DatapathBlock> {
        let pp = PartialProducts::new(m, ACC_WIDTH);
        let cpa_mul = Adder::new(a, PROD_WIDTH);
        let cpa_acc = Adder::new(a, ACC_WIDTH);
        let dru_depth = pp.ppgen_depth();
        let cel_depth = pp.cel_depth(0);
        vec![
            DatapathBlock {
                name: "DRU",
                gates: pp.ppgen_gates(),
                depth: dru_depth,
                input_depth: 0.0,
                duty: 1.0,
                on_cycle_path: true,
            },
            DatapathBlock {
                name: "CEL",
                gates: cel_gates(pp_bits(m), 0, PROD_WIDTH),
                depth: cel_depth,
                input_depth: dru_depth,
                duty: 1.0,
                on_cycle_path: true,
            },
            DatapathBlock {
                name: "CPA-mul",
                gates: cpa_mul.gates(),
                depth: cpa_mul.depth(),
                input_depth: dru_depth + cel_depth,
                duty: 1.0,
                on_cycle_path: true,
            },
            DatapathBlock {
                name: "CPA-acc",
                gates: cpa_acc.gates(),
                depth: cpa_acc.depth(),
                input_depth: dru_depth + cel_depth + cpa_mul.depth(),
                duty: 1.0,
                on_cycle_path: true,
            },
            DatapathBlock {
                name: "regs",
                gates: GateCounts {
                    reg: 2 * OP_WIDTH as u64 + ACC_WIDTH as u64,
                    ..Default::default()
                },
                depth: 0.0,
                input_depth: 0.0,
                duty: 1.0,
                on_cycle_path: true,
            },
        ]
    }

    fn tcd_blocks() -> Vec<DatapathBlock> {
        let pp = PartialProducts::new(MultKind::Simple, ACC_WIDTH);
        let pcpa = Adder::new(AdderKind::KoggeStone, ACC_WIDTH);
        let dru_depth = pp.ppgen_depth();
        // Two extra rows in the tree: the ORU and CBU planes. The CB bits
        // target incomplete compressor columns (paper §III-A) so the level
        // count barely moves; the bit population grows by the plane bits,
        // and steering them into the right incomplete columns costs one
        // mux level (+2τ).
        let cel_depth = pp.cel_depth(2) + 2.0;
        vec![
            DatapathBlock {
                name: "DRU",
                gates: pp.ppgen_gates(),
                depth: dru_depth,
                input_depth: 0.0,
                duty: 1.0,
                on_cycle_path: true,
            },
            DatapathBlock {
                name: "CEL",
                gates: cel_gates(pp_bits(MultKind::Simple), 2 * ACC_WIDTH as u64, ACC_WIDTH),
                depth: cel_depth,
                input_depth: dru_depth,
                duty: 1.0,
                on_cycle_path: true,
            },
            DatapathBlock {
                name: "GEN",
                gates: GateCounts {
                    simple: ACC_WIDTH as u64,
                    xor: ACC_WIDTH as u64,
                    ..Default::default()
                },
                depth: 1.0,
                input_depth: dru_depth + cel_depth,
                duty: 1.0,
                on_cycle_path: true,
            },
            DatapathBlock {
                name: "PCPA",
                gates: pcpa.gates(),
                // The PCPA's own depth minus the GEN layer it shares.
                depth: pcpa.pcpa_depth(),
                input_depth: 0.0, // reads ORU/CBU registers
                duty: TCD_PCPA_DUTY,
                on_cycle_path: false, // hidden in the extra CPM cycle
            },
            DatapathBlock {
                name: "regs",
                // input regs + ORU + CBU (the carry-buffer unit is the
                // TCD-MAC's extra state).
                gates: GateCounts {
                    reg: 2 * OP_WIDTH as u64 + 2 * ACC_WIDTH as u64,
                    ..Default::default()
                },
                depth: 0.0,
                input_depth: 0.0,
                duty: 1.0,
                on_cycle_path: true,
            },
        ]
    }

    /// Per-cycle critical-path depth (τ) — sets the clock.
    pub fn cycle_depth(&self) -> Depth {
        let logic: Depth = self
            .blocks
            .iter()
            .filter(|b| b.on_cycle_path)
            .map(|b| b.depth)
            .sum();
        // The deferred PCPA must still fit in one (the extra CPM) cycle.
        let off_path = self
            .blocks
            .iter()
            .filter(|b| !b.on_cycle_path)
            .map(|b| b.depth)
            .fold(0.0, f64::max);
        logic.max(off_path)
    }

    /// Total NAND2-equivalents including timing-pressure upsizing.
    pub fn nand2_total(&self) -> f64 {
        let raw: f64 = self.blocks.iter().map(|b| b.gates.nand2_equiv()).sum();
        raw * upsize_factor(self.cycle_depth())
    }

    /// Per-cycle switched NAND2-equivalents at activity factor `alpha`,
    /// including the per-block glitch multipliers.
    pub fn switched_nand2_per_cycle(&self, alpha: f64) -> f64 {
        self.blocks
            .iter()
            .map(|b| alpha * b.gates.nand2_equiv() * glitch_factor(b.input_depth) * b.duty)
            .sum()
    }

    /// Full PPA report at the PE voltage domain.
    pub fn report(&self, tech: &TechParams, alpha: f64) -> PpaReport {
        let dom = VoltageDomain::PE;
        let delay_ns = tech.delay_ns(self.cycle_depth(), dom);
        let nand2 = self.nand2_total();
        let area_um2 = tech.area_um2(nand2);
        let e_cycle_pj = tech.dyn_energy_pj(self.switched_nand2_per_cycle(alpha), dom);
        let leak_uw = tech.leak_uw(nand2, dom);
        // pJ per ns == mW; power averaged at fmax.
        let power_uw = e_cycle_pj / delay_ns * 1000.0 + leak_uw;
        PpaReport {
            name: self.kind.name(),
            area_um2,
            power_uw,
            delay_ns,
        }
    }
}

/// The paper's power protocol: 20K cycles of random input data.
pub const ACTIVITY_CYCLES: usize = 20_000;

/// Measure the switching-activity factor of a MAC design point by running
/// the functional model on `cycles` random 16-bit input pairs (streams of
/// 64 with a resolution between streams, matching the OS dataflow) and
/// normalizing the monitored-bus toggle count.
pub fn measure_activity(kind: MacKind, cycles: usize, seed: u64) -> f64 {
    let mut mac = kind.build();
    let mut rng = SplitMix64::new(seed);
    let mut i = 0usize;
    while i < cycles {
        mac.reset();
        for _ in 0..64.min(cycles - i) {
            mac.step(rng.next_i16(), rng.next_i16());
            i += 1;
        }
        mac.finalize();
    }
    mac.toggles() as f64 / mac.monitored_bits().max(1) as f64
}

/// PPA of one design point (activity measured with the default protocol).
pub fn mac_ppa(kind: MacKind) -> PpaReport {
    let model = MacPpaModel::assemble(kind);
    let alpha = measure_activity(kind, ACTIVITY_CYCLES, 0x7C0_FFEE);
    model.report(&TechParams::DEFAULT, alpha)
}

/// Regenerate Table I: all eight conventional MACs plus the TCD-MAC,
/// in the paper's row order.
pub fn table1_reports() -> Vec<PpaReport> {
    MacKind::table1_order().into_iter().map(mac_ppa).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ppa::paper;

    #[test]
    fn tcd_has_shortest_cycle() {
        let tcd = MacPpaModel::assemble(MacKind::Tcd).cycle_depth();
        for kind in MacKind::table1_order() {
            if kind != MacKind::Tcd {
                let d = MacPpaModel::assemble(kind).cycle_depth();
                assert!(tcd < d, "TCD {tcd} vs {} {d}", kind.name());
            }
        }
    }

    #[test]
    fn tcd_has_smallest_area() {
        let reports = table1_reports();
        let tcd = reports.last().unwrap();
        for r in &reports[..reports.len() - 1] {
            assert!(tcd.area_um2 < r.area_um2, "TCD vs {}", r.name);
        }
    }

    #[test]
    fn tcd_pdp_improvement_in_paper_band() {
        // Paper §IV-B: "46% to 62% improvement in PDP". Our analytic
        // substrate over-credits the TCD-MAC by ~10–15pp (its conventional
        // baselines pay two fully-glitching CPAs per cycle, where real
        // layout absorbs part of that in sizing) — see EXPERIMENTS.md §E1.
        // Band: paper's claim −12pp / +18pp.
        let reports = table1_reports();
        let tcd = *reports.last().unwrap();
        for r in &reports[..reports.len() - 1] {
            let imp = tcd.pdp_improvement_pct(r);
            assert!(
                (paper::claims::PDP_IMPROVEMENT_PCT.0 - 12.0
                    ..=paper::claims::PDP_IMPROVEMENT_PCT.1 + 18.0)
                    .contains(&imp),
                "PDP improvement vs {} = {imp:.1}%",
                r.name
            );
        }
    }

    #[test]
    fn delays_land_near_paper() {
        // Delay columns within ±35% of Table I per design point.
        let reports = table1_reports();
        for (r, p) in reports.iter().zip(paper::TABLE1) {
            assert_eq!(r.name, p.name);
            let rel = (r.delay_ns - p.delay_ns).abs() / p.delay_ns;
            assert!(rel < 0.35, "{}: {} vs paper {}", r.name, r.delay_ns, p.delay_ns);
        }
    }

    #[test]
    fn ks_faster_than_bk_everywhere() {
        use crate::bitsim::{AdderKind::*, MultKind::*};
        for m in [Simple, BoothRadix2, BoothRadix4, BoothRadix8] {
            let ks = MacPpaModel::assemble(MacKind::Conv(m, KoggeStone)).cycle_depth();
            let bk = MacPpaModel::assemble(MacKind::Conv(m, BrentKung)).cycle_depth();
            assert!(ks < bk);
        }
    }

    #[test]
    fn activity_factor_sane() {
        for kind in MacKind::table1_order() {
            let a = measure_activity(kind, 2_000, 1);
            assert!(a > 0.05 && a < 0.95, "{}: alpha={a}", kind.name());
        }
    }
}
