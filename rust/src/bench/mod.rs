//! Generators for every table and figure of the paper's evaluation
//! (§IV), shared between the CLI (`tcd-npe table1 …`) and the
//! `cargo bench` harnesses. Each generator returns structured rows *and*
//! renders the paper-shaped text table.

pub mod ablation;
pub mod convnet;
pub mod dataflow;
pub mod exec;
pub mod fig10;
pub mod fleet;
pub mod graph;
pub mod harness;
pub mod obs;
pub mod table1;
pub mod table2;
pub mod table3;

pub use convnet::{conv_rows, render_conv_table, ConvRow, CONV_BATCHES};
pub use dataflow::{
    dataflow_json, dataflow_rows, render_dataflow_table, DataflowRow, DATAFLOW_BATCHES,
};
pub use exec::{
    exec_json, exec_row, exec_rows, exec_workloads, render_exec_table, ExecRow, ExecWorkload,
    EXEC_BATCHES,
};
pub use fig10::{fig10_rows, render_fig10, Fig10Row};
pub use fleet::{
    admission_rows, elastic_rows, fleet_json, fleet_row, fleet_rows, mapper_cache_bench,
    render_admission_table, render_elastic_table, render_fleet_table, render_tenant_table,
    tenant_rows, AdmissionRow, ElasticRow, FleetRow, MapperCacheBench, TenantRow,
    ELASTIC_MAX_DEVICES, ELASTIC_MIN_DEVICES, FLEET_DEVICE_COUNTS, TENANT_POOL_DEVICES,
};
pub use graph::{graph_json, graph_rows, render_graph_table, GraphRow, GRAPH_BATCHES};
pub use harness::BenchTimer;
pub use obs::{obs_bench, obs_json, render_obs, ObsBench, OBS_BENCH_REQUESTS, OBS_BENCH_RUNS};
pub use table1::{render_table1, table1_rows};
pub use table2::{render_table2, table2_rows, Table2Row, STREAM_SIZES};
pub use table3::render_table3;

use crate::model::zoo::benchmarks;
use crate::util::TextTable;

/// Render Table IV (the benchmark suite itself).
pub fn render_table4() -> String {
    let mut t = TextTable::new(vec!["Application", "Dataset", "Topology", "MACs/sample"]);
    for b in benchmarks() {
        t.row(vec![
            b.application.to_string(),
            b.dataset.to_string(),
            b.topology.display(),
            b.topology.macs_per_sample().to_string(),
        ]);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    #[test]
    fn table4_renders_all_rows() {
        let s = super::render_table4();
        assert!(s.contains("MNIST"));
        assert!(s.contains("784:700:10"));
        assert_eq!(s.lines().count(), 2 + 7);
    }
}
