//! Observability overhead bench: the same closed-loop serving run with
//! instrumentation off, with tracing on, and with tracing + the
//! telemetry sampler on — interleaved, best-of-N per mode. Proves the
//! ISSUE bars — a traced service costs ≤ 5% wall time, and a traced **and
//! sampled** one stays within the same 5% — and records the exported
//! trace size, emitted as `BENCH_obs.json`.

use crate::coordinator::BatcherConfig;
use crate::mapper::NpeGeometry;
use crate::model::{benchmark_by_name, QuantizedMlp};
use crate::obs::SamplerConfig;
use crate::serve::NpeService;
use std::time::{Duration, Instant};

/// Requests per measured run.
pub const OBS_BENCH_REQUESTS: usize = 256;
/// Timed run triples (after one warmup triple); min-of-N per mode.
pub const OBS_BENCH_RUNS: usize = 5;

/// Instrumentation level of one measured run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Instrumentation {
    Off,
    Traced,
    /// Tracing plus the background telemetry sampler at a 5 ms period —
    /// 10x the default cadence, so the bar over-counts rather than
    /// under-counts sampling cost.
    TracedSampled,
}

/// Instrumented-vs-bare measurement of one serving workload.
#[derive(Debug, Clone)]
pub struct ObsBench {
    pub requests: usize,
    pub runs: usize,
    /// Best-of-runs wall time with instrumentation off, ns.
    pub untraced_ns: f64,
    /// Best-of-runs wall time with tracing on, ns.
    pub traced_ns: f64,
    /// Best-of-runs wall time with tracing + telemetry sampling on, ns.
    pub sampled_ns: f64,
    /// Spans recorded by one traced run (wall spans + batch records).
    pub trace_events: usize,
    /// Size of the exported Chrome-trace JSON, bytes.
    pub trace_bytes: usize,
}

impl ObsBench {
    /// traced / untraced wall time (1.0 means tracing was free).
    pub fn overhead_ratio(&self) -> f64 {
        if self.untraced_ns == 0.0 {
            1.0
        } else {
            self.traced_ns / self.untraced_ns
        }
    }

    /// (traced + sampled) / untraced wall time — the full-observability
    /// bar: spans, busy-lane stamps, and the sampler thread together.
    pub fn sampled_overhead_ratio(&self) -> f64 {
        if self.untraced_ns == 0.0 {
            1.0
        } else {
            self.sampled_ns / self.untraced_ns
        }
    }
}

fn iris() -> QuantizedMlp {
    let b = benchmark_by_name("Iris").expect("Iris is in Table IV");
    QuantizedMlp::synthesize(b.topology.clone(), 0xF1EE7)
}

/// One closed-loop run: submit every request, wait for every answer.
/// Returns (wall ns, recorded spans, exported trace bytes).
fn run_once(mlp: &QuantizedMlp, requests: usize, level: Instrumentation) -> (f64, usize, usize) {
    let mut builder = NpeService::builder(mlp.clone())
        .devices(vec![NpeGeometry::PAPER; 4])
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .tracing(level != Instrumentation::Off);
    if level == Instrumentation::TracedSampled {
        builder = builder.telemetry(SamplerConfig::default().with_period(Duration::from_millis(5)));
    }
    let service = builder.build().expect("valid obs bench config");
    let inputs = mlp.synth_inputs(requests, 0x0B5_BE4C);
    let t0 = Instant::now();
    let tickets: Vec<_> = inputs
        .into_iter()
        .map(|x| service.submit(x).expect("admitted"))
        .collect();
    for t in tickets {
        t.wait_timeout(Duration::from_secs(60)).expect("answered");
    }
    let elapsed = t0.elapsed().as_nanos() as f64;
    let (events, bytes) = if level == Instrumentation::Off {
        (0, 0)
    } else {
        let log = service.trace();
        (log.wall.len() + log.batches.len(), service.trace_json().len())
    };
    service.shutdown().expect("obs bench shutdown");
    (elapsed, events, bytes)
}

/// Interleave bare/traced/sampled runs (ABCABC…) so drift hits every
/// mode alike, and keep the best of each: min-of-N is the right
/// statistic for proving an *upper bound* on overhead, since every
/// slowdown is noise by definition.
pub fn obs_bench(runs: usize, requests: usize) -> ObsBench {
    let mlp = iris();
    run_once(&mlp, requests, Instrumentation::Off);
    run_once(&mlp, requests, Instrumentation::Traced);
    run_once(&mlp, requests, Instrumentation::TracedSampled);
    let (mut untraced, mut traced, mut sampled) = (f64::INFINITY, f64::INFINITY, f64::INFINITY);
    let (mut trace_events, mut trace_bytes) = (0, 0);
    for _ in 0..runs.max(1) {
        let (u, _, _) = run_once(&mlp, requests, Instrumentation::Off);
        untraced = untraced.min(u);
        let (t, events, bytes) = run_once(&mlp, requests, Instrumentation::Traced);
        traced = traced.min(t);
        trace_events = events;
        trace_bytes = bytes;
        let (s, _, _) = run_once(&mlp, requests, Instrumentation::TracedSampled);
        sampled = sampled.min(s);
    }
    ObsBench {
        requests,
        runs: runs.max(1),
        untraced_ns: untraced,
        traced_ns: traced,
        sampled_ns: sampled,
        trace_events,
        trace_bytes,
    }
}

/// One-paragraph text report.
pub fn render_obs(b: &ObsBench) -> String {
    format!(
        "obs overhead (Iris MLP, 4-device fleet, {} requests, best of {}):\n  \
         untraced {:.3} ms, traced {:.3} ms -> overhead {:.1}%\n  \
         traced+sampled {:.3} ms -> overhead {:.1}%\n  \
         one traced run recorded {} spans, {} bytes of Chrome trace",
        b.requests,
        b.runs,
        b.untraced_ns / 1e6,
        b.traced_ns / 1e6,
        (b.overhead_ratio() - 1.0) * 100.0,
        b.sampled_ns / 1e6,
        (b.sampled_overhead_ratio() - 1.0) * 100.0,
        b.trace_events,
        b.trace_bytes
    )
}

/// The `BENCH_obs.json` trajectory artifact (hand-rolled JSON — no
/// serde in the offline crate set).
pub fn obs_json(b: &ObsBench) -> String {
    format!(
        "{{\n  \"bench\": \"obs\",\n  \"requests\": {},\n  \"runs\": {},\n  \
         \"untraced_ms\": {:.4},\n  \"traced_ms\": {:.4},\n  \
         \"sampled_ms\": {:.4},\n  \
         \"overhead_ratio\": {:.4},\n  \"sampled_overhead_ratio\": {:.4},\n  \
         \"trace_events\": {},\n  \
         \"trace_bytes\": {}\n}}\n",
        b.requests,
        b.runs,
        b.untraced_ns / 1e6,
        b.traced_ns / 1e6,
        b.sampled_ns / 1e6,
        b.overhead_ratio(),
        b.sampled_overhead_ratio(),
        b.trace_events,
        b.trace_bytes
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_records_a_trace() {
        let b = obs_bench(1, 32);
        assert!(b.untraced_ns > 0.0 && b.traced_ns > 0.0 && b.sampled_ns > 0.0);
        assert!(b.trace_events > 0, "traced run recorded spans");
        assert!(b.trace_bytes > 2, "trace export is non-trivial JSON");
        let json = obs_json(&b);
        assert!(json.contains("\"bench\": \"obs\""));
        assert!(json.contains("\"overhead_ratio\""));
        assert!(json.contains("\"sampled_overhead_ratio\""));
        assert!(json.trim_end().ends_with('}'));
        assert!(render_obs(&b).contains("overhead"));
        assert!(render_obs(&b).contains("traced+sampled"));
    }

    /// The ISSUE acceptance bar: tracing costs ≤ 5% wall time. Timing
    /// bars are meaningless under debug codegen, so this arms in
    /// release runs only (`cargo test --release`).
    #[test]
    #[cfg_attr(debug_assertions, ignore = "timing bar is release-only")]
    fn tracing_overhead_within_five_percent() {
        let b = obs_bench(OBS_BENCH_RUNS, OBS_BENCH_REQUESTS);
        assert!(
            b.overhead_ratio() <= 1.05,
            "traced {:.2} ms vs untraced {:.2} ms — ratio {:.3} > 1.05",
            b.traced_ns / 1e6,
            b.untraced_ns / 1e6,
            b.overhead_ratio()
        );
    }

    /// The tentpole's bar: tracing *plus* the telemetry sampler (at 10x
    /// the default cadence) still costs ≤ 5% wall time. Release-only,
    /// like the bar above.
    #[test]
    #[cfg_attr(debug_assertions, ignore = "timing bar is release-only")]
    fn sampled_overhead_within_five_percent() {
        let b = obs_bench(OBS_BENCH_RUNS, OBS_BENCH_REQUESTS);
        assert!(
            b.sampled_overhead_ratio() <= 1.05,
            "traced+sampled {:.2} ms vs untraced {:.2} ms — ratio {:.3} > 1.05",
            b.sampled_ns / 1e6,
            b.untraced_ns / 1e6,
            b.sampled_overhead_ratio()
        );
    }
}
