//! Graph-workload table: scheduled rounds, execution time and energy of
//! the DAG zoo under fused vs unfused lowering — the trajectory table
//! `BENCH_graph.json` tracks across PRs.
//!
//! "Fused" is the production path: the pass pipeline (dead-node
//! elimination, ReLU folding, conv→pool fusion) followed by the
//! sibling-sharing lowering. "Unfused" executes the raw graph with one Γ
//! per parametric node — the baseline that shows what the graph compiler
//! buys.

use crate::dataflow::DataflowReport;
use crate::graph::{lower_graph, optimize, GraphEngine, PassStats, QuantizedGraph};
use crate::mapper::{MapperTree, NpeGeometry};
use crate::model::zoo::graph_benchmarks;
use crate::util::TextTable;

/// Default batch count for the graph sweeps (conv branches carry B·P
/// lowered rows, so this stays small like `CONV_BATCHES`).
pub const GRAPH_BATCHES: usize = 2;

/// One (DAG benchmark) measurement: fused vs unfused lowering on the
/// TCD dataflow.
#[derive(Debug, Clone)]
pub struct GraphRow {
    pub network: &'static str,
    pub dataset: &'static str,
    /// Raw-graph node count vs optimized node count.
    pub nodes_raw: usize,
    pub nodes_opt: usize,
    pub passes: PassStats,
    /// Algorithm-1 rounds of the fused (optimized + sibling-shared)
    /// lowering vs the per-node baseline.
    pub fused_rounds: usize,
    pub unfused_rounds: usize,
    pub fused: DataflowReport,
    pub unfused: DataflowReport,
}

impl GraphRow {
    /// Fraction of rounds the fused lowering saves (0.0 = none).
    pub fn round_saving(&self) -> f64 {
        if self.unfused_rounds == 0 {
            0.0
        } else {
            1.0 - self.fused_rounds as f64 / self.unfused_rounds as f64
        }
    }
}

/// Run the DAG zoo fused and unfused on the paper-geometry TCD NPE.
pub fn graph_rows(batches: usize) -> Vec<GraphRow> {
    let geom = NpeGeometry::PAPER;
    graph_benchmarks()
        .into_iter()
        .map(|b| {
            let raw = QuantizedGraph::synthesize(b.graph.clone(), 0x6A0DE);
            let (opt, passes) = optimize(&raw);
            let inputs = raw.synth_inputs(batches, 0xDA7A);

            // Throwaway lowerings just for round counts (the mapper DP is
            // memoized and costs microseconds).
            let mut mapper = MapperTree::new(geom);
            let fused_rounds =
                lower_graph(&mut mapper, None, &opt.graph, batches, true).total_rounds();
            let unfused_rounds =
                lower_graph(&mut mapper, None, &raw.graph, batches, false).total_rounds();

            let fused = GraphEngine::tcd(geom).execute(&opt, &inputs);
            let unfused = GraphEngine::tcd(geom).fused(false).execute(&raw, &inputs);
            assert_eq!(
                fused.outputs, unfused.outputs,
                "{}: lowering must never change values",
                b.network
            );
            GraphRow {
                network: b.network,
                dataset: b.dataset,
                nodes_raw: raw.graph.n_nodes(),
                nodes_opt: opt.graph.n_nodes(),
                passes,
                fused_rounds,
                unfused_rounds,
                fused,
                unfused,
            }
        })
        .collect()
}

/// Render the fused-vs-unfused comparison as a text table.
pub fn render_graph_table(rows: &[GraphRow], batches: usize) -> String {
    let mut t = TextTable::new(vec![
        "Network",
        "Nodes",
        "Folded",
        "Rounds (fused)",
        "Rounds (unfused)",
        "Saved",
        "Cycles (fused)",
        "Time (us)",
        "Energy (uJ)",
        "vs unfused",
    ]);
    for r in rows {
        t.row(vec![
            r.network.to_string(),
            format!("{} -> {}", r.nodes_raw, r.nodes_opt),
            format!(
                "{}a+{}p",
                r.passes.activations_folded, r.passes.pools_fused
            ),
            r.fused_rounds.to_string(),
            r.unfused_rounds.to_string(),
            format!("{:.0}%", r.round_saving() * 100.0),
            r.fused.cycles.to_string(),
            format!("{:.1}", r.fused.time_us()),
            format!("{:.2}", r.fused.energy_uj()),
            format!("{:.2}x", r.unfused.time_ns / r.fused.time_ns),
        ]);
    }
    format!(
        "DAG zoo on the 16x8 TCD-NPE, B={batches} (graph-compiler lowering)\n{}",
        t.render()
    )
}

/// Serialize the comparison as the `BENCH_graph.json` trajectory
/// artifact. Hand-rolled JSON — the offline crate set has no serde.
pub fn graph_json(rows: &[GraphRow], batches: usize) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"graph\",\n");
    s.push_str(&format!("  \"batches\": {batches},\n"));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"network\": \"{}\", \"nodes_raw\": {}, \"nodes_opt\": {}, \
             \"activations_folded\": {}, \"pools_fused\": {}, \
             \"fused_rounds\": {}, \"unfused_rounds\": {}, \"round_saving\": {:.4}, \
             \"fused_cycles\": {}, \"unfused_cycles\": {}, \
             \"fused_time_us\": {:.3}, \"unfused_time_us\": {:.3}, \
             \"fused_energy_uj\": {:.4}, \"unfused_energy_uj\": {:.4}}}{}\n",
            r.network,
            r.nodes_raw,
            r.nodes_opt,
            r.passes.activations_folded,
            r.passes.pools_fused,
            r.fused_rounds,
            r.unfused_rounds,
            r.round_saving(),
            r.fused.cycles,
            r.unfused.cycles,
            r.fused.time_us(),
            r.unfused.time_us(),
            r.fused.energy_uj(),
            r.unfused.energy_uj(),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fused_never_worse_and_strictly_better_somewhere() {
        let rows = graph_rows(2);
        assert_eq!(rows.len(), 3);
        for r in &rows {
            assert!(
                r.fused_rounds <= r.unfused_rounds,
                "{}: fused {} > unfused {}",
                r.network,
                r.fused_rounds,
                r.unfused_rounds
            );
            assert!(r.fused.cycles <= r.unfused.cycles, "{}", r.network);
        }
        // The ISSUE acceptance bar: at least one zoo entry saves rounds.
        assert!(
            rows.iter().any(|r| r.fused_rounds < r.unfused_rounds),
            "sibling sharing must save rounds on some entry"
        );
        // By construction that entry is the two-branch Inception.
        let inception = rows.iter().find(|r| r.network == "InceptionMini").unwrap();
        assert!(inception.fused_rounds < inception.unfused_rounds);
        assert!(inception.round_saving() > 0.0);
    }

    #[test]
    fn render_and_json_are_shaped() {
        let rows = graph_rows(1);
        let table = render_graph_table(&rows, 1);
        assert!(table.contains("TinyResNet"));
        assert!(table.contains("InceptionMini"));
        assert!(table.contains("Rounds (fused)"));
        let json = graph_json(&rows, 1);
        assert!(json.contains("\"bench\": \"graph\""));
        assert!(json.contains("\"network\": \"ResMLP\""));
        assert!(json.trim_end().ends_with('}'));
    }
}
