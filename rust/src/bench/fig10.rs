//! Fig. 10 generator: execution time (top) and energy breakdown (bottom)
//! of the four dataflows across the seven Table-IV benchmarks.

use crate::dataflow::{
    DataflowEngine, DataflowReport, NlrEngine, OsEngine, RnaEngine,
};
use crate::mapper::NpeGeometry;
use crate::model::zoo::benchmarks;
use crate::model::QuantizedMlp;
use crate::util::TextTable;

/// Batch count used for the Fig.-10 sweeps (the paper does not state its
/// batch size; 10 keeps every benchmark's schedule multi-roll and is the
/// value DESIGN.md commits to).
pub const FIG10_BATCHES: usize = 10;

/// One (benchmark × dataflow) measurement.
#[derive(Debug, Clone)]
pub struct Fig10Row {
    pub dataset: &'static str,
    pub report: DataflowReport,
}

/// Run all four dataflows over all seven benchmarks.
pub fn fig10_rows(batches: usize) -> Vec<Fig10Row> {
    let geom = NpeGeometry::PAPER;
    let mut out = Vec::new();
    for b in benchmarks() {
        let mlp = QuantizedMlp::synthesize(b.topology.clone(), 0xF16_10);
        let inputs = mlp.synth_inputs(batches, 0xDA7A);
        let mut engines: Vec<Box<dyn DataflowEngine>> = vec![
            Box::new(OsEngine::tcd(geom)),
            Box::new(OsEngine::conventional(geom)),
            Box::new(NlrEngine::new(geom)),
            Box::new(RnaEngine::new(geom)),
        ];
        for e in engines.iter_mut() {
            out.push(Fig10Row {
                dataset: b.dataset,
                report: e.execute(&mlp, &inputs),
            });
        }
    }
    out
}

/// Render both Fig. 10 panels as text tables.
pub fn render_fig10(rows: &[Fig10Row]) -> String {
    let mut time = TextTable::new(vec![
        "Benchmark",
        "Dataflow",
        "Cycles",
        "Time (us)",
        "vs TCD",
    ]);
    let mut energy = TextTable::new(vec![
        "Benchmark",
        "Dataflow",
        "PE dyn (uJ)",
        "PE leak (uJ)",
        "Mem dyn (uJ)",
        "Mem leak (uJ)",
        "Total (uJ)",
        "vs TCD",
    ]);
    // Group rows by dataset (they arrive in order, 4 per dataset).
    for chunk in rows.chunks(4) {
        let tcd_time = chunk[0].report.time_ns;
        let tcd_energy = chunk[0].report.energy.on_chip_pj();
        for r in chunk {
            time.row(vec![
                r.dataset.to_string(),
                r.report.dataflow.to_string(),
                r.report.cycles.to_string(),
                format!("{:.1}", r.report.time_us()),
                format!("{:.2}x", r.report.time_ns / tcd_time),
            ]);
            let e = &r.report.energy;
            energy.row(vec![
                r.dataset.to_string(),
                r.report.dataflow.to_string(),
                format!("{:.2}", e.pe_dynamic_pj / 1e6),
                format!("{:.2}", e.pe_leak_pj / 1e6),
                format!("{:.2}", e.mem_dynamic_pj / 1e6),
                format!("{:.2}", e.mem_leak_pj / 1e6),
                format!("{:.2}", e.on_chip_pj() / 1e6),
                format!("{:.2}x", e.on_chip_pj() / tcd_energy),
            ]);
        }
    }
    format!(
        "Fig. 10 (top): execution time, B={FIG10_BATCHES}\n{}\nFig. 10 (bottom): energy breakdown\n{}",
        time.render(),
        energy.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_rows() -> Vec<Fig10Row> {
        // Smaller batch to keep the test fast; trends must already hold.
        fig10_rows(4)
    }

    #[test]
    fn tcd_wins_every_benchmark() {
        // The paper's headline claim (Fig. 10): TCD-NPE is the fastest and
        // the least energy-consuming configuration on every benchmark.
        for chunk in small_rows().chunks(4) {
            let tcd = &chunk[0];
            assert!(tcd.report.dataflow.contains("TCD"));
            for other in &chunk[1..] {
                assert!(
                    tcd.report.time_ns < other.report.time_ns,
                    "{}: TCD {:.0} vs {} {:.0}",
                    tcd.dataset,
                    tcd.report.time_ns,
                    other.report.dataflow,
                    other.report.time_ns
                );
                assert!(
                    tcd.report.energy.on_chip_pj() < other.report.energy.on_chip_pj(),
                    "{}: energy vs {}",
                    tcd.dataset,
                    other.report.dataflow
                );
            }
        }
    }

    #[test]
    fn tcd_roughly_halves_conv_os_time() {
        // Paper: "execution time of the TCD-NPE is almost half" of the
        // conventional OS/NLR NPEs. Cycle counts differ by rolls/(I+1);
        // the win comes from the 1.57-vs-2.6 ns clock. Accept 0.45–0.75×.
        for chunk in small_rows().chunks(4) {
            let ratio = chunk[0].report.time_ns / chunk[1].report.time_ns;
            assert!(
                ratio > 0.40 && ratio < 0.80,
                "{}: ratio {:.2}",
                chunk[0].dataset,
                ratio
            );
        }
    }

    #[test]
    fn all_dataflows_agree_on_outputs() {
        for chunk in small_rows().chunks(4) {
            for other in &chunk[1..] {
                assert_eq!(chunk[0].report.outputs, other.report.outputs);
            }
        }
    }

    #[test]
    fn render_contains_both_panels() {
        let s = render_fig10(&fig10_rows(2));
        assert!(s.contains("execution time"));
        assert!(s.contains("energy breakdown"));
        assert!(s.contains("MNIST"));
    }
}
