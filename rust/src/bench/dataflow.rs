//! Dataflow-autotuner table: every zoo model priced under all four fixed
//! dataflows and under the per-layer autotuned plan — the trajectory
//! table `BENCH_dataflow.json` tracks across PRs.
//!
//! MLP rows are **measured** (the fixed-OS and autotuned engines both
//! execute, and the measured cycles must equal the analytical
//! prediction exactly); CNN and DAG rows are **predicted** over the same
//! lowered Γ sequence the OS engine executes (their engines are
//! OS-native, so the plan is advisory — the number is what a
//! reconfigurable array would buy).
//!
//! The acceptance bar asserted by this module's tests: the autotuned
//! plan is never worse than fixed-OS on any zoo model, and strictly
//! better on at least one.

use crate::autotune::{
    plan_cnn, plan_graph, plan_mlp, AutotunedEngine, CostModel, Dataflow, Objective,
};
use crate::dataflow::{DataflowEngine, OsEngine};
use crate::mapper::NpeGeometry;
use crate::model::zoo::{benchmarks, cnn_benchmarks, graph_benchmarks};
use crate::model::QuantizedMlp;
use crate::util::TextTable;

/// Default batch count for the dataflow sweep (the Γ(B, I, U) shape the
/// serving path sees; small B is where OS leaves the most on the table).
pub const DATAFLOW_BATCHES: usize = 4;

/// One zoo model priced four fixed ways and autotuned.
#[derive(Debug, Clone)]
pub struct DataflowRow {
    pub network: &'static str,
    /// `mlp` | `cnn` | `graph`.
    pub family: &'static str,
    /// Compact plan, e.g. `os→nlr`.
    pub plan: String,
    pub n_switches: usize,
    /// Predicted all-fixed cycle totals in [`Dataflow::ALL`] lane order
    /// (no switch penalties — a fixed plan never reconfigures).
    pub fixed_cycles: [u64; 4],
    /// The autotuned plan's predicted total (switch penalties included).
    pub autotuned_cycles: u64,
    /// Measured engine cycles (MLP rows only; CNN/DAG engines are
    /// OS-native, so there is nothing mixed to measure).
    pub measured_os: Option<u64>,
    pub measured_autotuned: Option<u64>,
}

impl DataflowRow {
    /// Predicted all-OS baseline (what the engine runs without a tuner).
    pub fn os_cycles(&self) -> u64 {
        self.fixed_cycles[Dataflow::Os.lane()]
    }

    /// Cycles saved by autotuning over fixed-OS, as a ratio ≥ 1.0.
    pub fn speedup(&self) -> f64 {
        self.os_cycles() as f64 / self.autotuned_cycles.max(1) as f64
    }
}

/// Per-lane fixed totals for one plan: each step's candidate cost in
/// that lane, summed (fixed dataflows pay no switch penalty).
fn fixed_totals(plan: &crate::autotune::DataflowPlan) -> [u64; 4] {
    let mut t = [0u64; 4];
    for step in &plan.steps {
        for d in Dataflow::ALL {
            t[d.lane()] += step.candidates[d.lane()].cycles;
        }
    }
    t
}

/// Price (and for MLPs, execute) the whole zoo on the paper-geometry
/// TCD NPE.
pub fn dataflow_rows(batches: usize) -> Vec<DataflowRow> {
    let geom = NpeGeometry::PAPER;
    let mut rows = Vec::new();

    for b in benchmarks() {
        let mut model = CostModel::new(geom);
        let plan = plan_mlp(&mut model, Objective::Cycles, &b.topology, batches);
        let mlp = QuantizedMlp::synthesize(b.topology.clone(), 0xDF_01);
        let inputs = mlp.synth_inputs(batches, 0xDF_02);
        let os = OsEngine::tcd(geom).execute(&mlp, &inputs);
        let auto = AutotunedEngine::new(geom).execute(&mlp, &inputs);
        assert_eq!(auto.outputs, os.outputs, "{}: autotuning must never change values", b.dataset);
        rows.push(DataflowRow {
            network: b.dataset,
            family: "mlp",
            plan: plan.summary(),
            n_switches: plan.n_switches(),
            fixed_cycles: fixed_totals(&plan),
            autotuned_cycles: plan.total_cycles(),
            measured_os: Some(os.cycles),
            measured_autotuned: Some(auto.cycles),
        });
    }

    for b in cnn_benchmarks() {
        let mut model = CostModel::new(geom);
        let plan = plan_cnn(&mut model, Objective::Cycles, &b.topology, 1);
        rows.push(DataflowRow {
            network: b.network,
            family: "cnn",
            plan: plan.summary(),
            n_switches: plan.n_switches(),
            fixed_cycles: fixed_totals(&plan),
            autotuned_cycles: plan.total_cycles(),
            measured_os: None,
            measured_autotuned: None,
        });
    }

    for b in graph_benchmarks() {
        let mut model = CostModel::new(geom);
        let plan = plan_graph(&mut model, Objective::Cycles, &b.graph, 2);
        rows.push(DataflowRow {
            network: b.network,
            family: "graph",
            plan: plan.summary(),
            n_switches: plan.n_switches(),
            fixed_cycles: fixed_totals(&plan),
            autotuned_cycles: plan.total_cycles(),
            measured_os: None,
            measured_autotuned: None,
        });
    }

    rows
}

/// Render the sweep as a text table.
pub fn render_dataflow_table(rows: &[DataflowRow], batches: usize) -> String {
    let mut t = TextTable::new(vec![
        "Network", "Family", "Plan", "Sw", "OS", "WS", "NLR", "RNA", "Autotuned", "vs OS",
    ]);
    for r in rows {
        t.row(vec![
            r.network.to_string(),
            r.family.to_string(),
            r.plan.clone(),
            r.n_switches.to_string(),
            r.fixed_cycles[0].to_string(),
            r.fixed_cycles[1].to_string(),
            r.fixed_cycles[2].to_string(),
            r.fixed_cycles[3].to_string(),
            r.autotuned_cycles.to_string(),
            format!("{:.2}x", r.speedup()),
        ]);
    }
    format!(
        "Dataflow autotuner on the 16x8 TCD-NPE, MLP B={batches} (cycles; \
         MLP rows measured, CNN/DAG rows predicted)\n{}",
        t.render()
    )
}

/// Serialize the sweep as the `BENCH_dataflow.json` trajectory artifact.
/// Hand-rolled JSON — the offline crate set has no serde.
pub fn dataflow_json(rows: &[DataflowRow], batches: usize) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"dataflow\",\n");
    s.push_str(&format!("  \"batches\": {batches},\n"));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let opt = |v: Option<u64>| v.map_or("null".to_string(), |c| c.to_string());
        s.push_str(&format!(
            "    {{\"network\": \"{}\", \"family\": \"{}\", \"plan\": \"{}\", \
             \"switches\": {}, \"os_cycles\": {}, \"ws_cycles\": {}, \
             \"nlr_cycles\": {}, \"rna_cycles\": {}, \"autotuned_cycles\": {}, \
             \"measured_os\": {}, \"measured_autotuned\": {}, \
             \"speedup_vs_os\": {:.4}}}{}\n",
            r.network,
            r.family,
            r.plan,
            r.n_switches,
            r.fixed_cycles[0],
            r.fixed_cycles[1],
            r.fixed_cycles[2],
            r.fixed_cycles[3],
            r.autotuned_cycles,
            opt(r.measured_os),
            opt(r.measured_autotuned),
            r.speedup(),
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn autotuned_never_worse_and_strictly_better_somewhere() {
        let rows = dataflow_rows(DATAFLOW_BATCHES);
        assert_eq!(rows.len(), 7 + 2 + 3, "whole zoo priced");
        for r in &rows {
            assert!(
                r.autotuned_cycles <= r.os_cycles(),
                "{}: autotuned {} > fixed-OS {}",
                r.network,
                r.autotuned_cycles,
                r.os_cycles()
            );
            assert!(r.speedup() >= 1.0, "{}", r.network);
        }
        // The ISSUE acceptance bar: at least one zoo entry strictly wins.
        assert!(
            rows.iter().any(|r| r.autotuned_cycles < r.os_cycles()),
            "autotuning must strictly beat fixed-OS on some zoo entry"
        );
    }

    #[test]
    fn mlp_measurements_match_predictions_exactly() {
        let rows = dataflow_rows(2);
        for r in rows.iter().filter(|r| r.family == "mlp") {
            assert_eq!(
                r.measured_os,
                Some(r.os_cycles()),
                "{}: fixed-OS prediction must be exact",
                r.network
            );
            assert_eq!(
                r.measured_autotuned,
                Some(r.autotuned_cycles),
                "{}: autotuned prediction must be exact",
                r.network
            );
        }
        for r in rows.iter().filter(|r| r.family != "mlp") {
            assert_eq!(r.measured_os, None);
            assert_eq!(r.measured_autotuned, None);
        }
    }

    #[test]
    fn render_and_json_are_shaped() {
        let rows = dataflow_rows(1);
        let table = render_dataflow_table(&rows, 1);
        assert!(table.contains("MNIST"));
        assert!(table.contains("LeNet-5"));
        assert!(table.contains("Autotuned"));
        let json = dataflow_json(&rows, 1);
        assert!(json.contains("\"bench\": \"dataflow\""));
        assert!(json.contains("\"network\": \"InceptionMini\""));
        assert!(json.contains("\"measured_os\": null"), "CNN/DAG rows are predicted-only");
        assert!(json.trim_end().ends_with('}'));
    }
}
