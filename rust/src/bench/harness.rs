//! Minimal wall-clock bench harness (criterion is not in the offline
//! crate set). Measures median-of-runs with warmup; used by the
//! `cargo bench` targets.

use std::time::Instant;

/// A simple timer harness: warms up, runs `iters` timed iterations,
/// reports min/median/mean.
pub struct BenchTimer {
    pub name: String,
    samples_ns: Vec<f64>,
}

impl BenchTimer {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), samples_ns: Vec::new() }
    }

    /// Run `f` `iters` times after `warmup` unmeasured runs.
    pub fn run<T>(&mut self, warmup: usize, iters: usize, mut f: impl FnMut() -> T) {
        for _ in 0..warmup {
            std::hint::black_box(f());
        }
        for _ in 0..iters.max(1) {
            let t0 = Instant::now();
            std::hint::black_box(f());
            self.samples_ns.push(t0.elapsed().as_nanos() as f64);
        }
    }

    pub fn median_ns(&self) -> f64 {
        let mut s = self.samples_ns.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        if s.is_empty() {
            0.0
        } else {
            s[s.len() / 2]
        }
    }

    pub fn mean_ns(&self) -> f64 {
        if self.samples_ns.is_empty() {
            0.0
        } else {
            self.samples_ns.iter().sum::<f64>() / self.samples_ns.len() as f64
        }
    }

    pub fn min_ns(&self) -> f64 {
        self.samples_ns.iter().cloned().fold(f64::INFINITY, f64::min)
    }

    /// One-line report in a `cargo bench`-like format.
    pub fn report(&self) -> String {
        fn human(ns: f64) -> String {
            if ns >= 1e9 {
                format!("{:.3} s", ns / 1e9)
            } else if ns >= 1e6 {
                format!("{:.3} ms", ns / 1e6)
            } else if ns >= 1e3 {
                format!("{:.3} µs", ns / 1e3)
            } else {
                format!("{:.0} ns", ns)
            }
        }
        format!(
            "{:<48} min {:>12}  median {:>12}  mean {:>12}  ({} samples)",
            self.name,
            human(self.min_ns()),
            human(self.median_ns()),
            human(self.mean_ns()),
            self.samples_ns.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut t = BenchTimer::new("spin");
        t.run(1, 5, || {
            let mut x = 0u64;
            for i in 0..1000 {
                x = x.wrapping_add(i);
            }
            x
        });
        assert!(t.median_ns() > 0.0);
        assert!(t.min_ns() <= t.median_ns());
        assert!(t.report().contains("spin"));
    }
}
