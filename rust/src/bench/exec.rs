//! Execution-core bench: wall-clock of the three roll backends
//! (`bitexact` / `fast` / `parallel`) over Table-IV MLPs, LeNet-5 and
//! the DAG zoo — the trajectory table future PRs track via
//! `BENCH_exec.json`.
//!
//! The acceptance bar this file proves: the `Parallel` backend is
//! bit-identical to `BitExact` on every workload while being ≥10×
//! faster on at least one Table-IV workload (MNIST clears it by orders
//! of magnitude — gate-accurate carry-save planes vs host-parallel i64
//! dot products).

use crate::conv::QuantizedCnn;
use crate::dataflow::{DataflowEngine, DataflowReport};
use crate::exec::BackendKind;
use crate::graph::QuantizedGraph;
use crate::mapper::NpeGeometry;
use crate::model::zoo::lenet5;
use crate::model::{benchmark_by_name, graph_benchmarks, QuantizedMlp};
use crate::util::TextTable;
use std::time::Instant;

/// Default batch count of the sweep (CNN/graph workloads clamp to 2 —
/// their lowered Γ carries B·P rows, so 2 samples already schedule
/// hundreds of GEMM rows).
pub const EXEC_BATCHES: usize = 4;

/// One workload of the backend sweep.
#[derive(Clone)]
pub enum ExecWorkload {
    Mlp { name: String, mlp: QuantizedMlp },
    Cnn { name: String, cnn: QuantizedCnn },
    Graph { name: String, graph: QuantizedGraph },
}

impl ExecWorkload {
    pub fn name(&self) -> &str {
        match self {
            ExecWorkload::Mlp { name, .. }
            | ExecWorkload::Cnn { name, .. }
            | ExecWorkload::Graph { name, .. } => name,
        }
    }

    pub fn family(&self) -> &'static str {
        match self {
            ExecWorkload::Mlp { .. } => "mlp",
            ExecWorkload::Cnn { .. } => "cnn",
            ExecWorkload::Graph { .. } => "graph",
        }
    }

    /// Whether this row is a Table-IV benchmark (the ≥10× acceptance
    /// bar is anchored to one of these).
    pub fn is_table4(&self) -> bool {
        matches!(self, ExecWorkload::Mlp { .. })
    }

    fn batches(&self, batches: usize) -> usize {
        match self {
            ExecWorkload::Mlp { .. } => batches,
            // Conv lowerings blow B up to B·P rows; keep wall time sane.
            ExecWorkload::Cnn { .. } | ExecWorkload::Graph { .. } => batches.min(2),
        }
    }

    /// MACs per executed batch (reporting only).
    fn macs(&self, batches: usize) -> u64 {
        let b = self.batches(batches) as u64;
        b * match self {
            ExecWorkload::Mlp { mlp, .. } => mlp.topology.macs_per_sample(),
            ExecWorkload::Cnn { cnn, .. } => cnn.topology.macs_per_sample(),
            ExecWorkload::Graph { graph, .. } => graph.graph.macs_per_sample(),
        }
    }

    fn reference(&self, batches: usize) -> Vec<Vec<i16>> {
        let b = self.batches(batches);
        match self {
            ExecWorkload::Mlp { mlp, .. } => mlp.forward_batch(&mlp.synth_inputs(b, 0xE8EC)),
            ExecWorkload::Cnn { cnn, .. } => cnn.forward_batch(&cnn.synth_inputs(b, 0xE8EC)),
            ExecWorkload::Graph { graph, .. } => {
                graph.forward_batch(&graph.synth_inputs(b, 0xE8EC))
            }
        }
    }

    /// Execute once on `backend`; returns the report and wall ms.
    ///
    /// Input synthesis happens outside the timed window — it is workload
    /// setup, not backend work, and would otherwise compress the small
    /// rows' speedups. Engine construction stays inside: the mapper memo
    /// is part of what an engine costs.
    fn execute(&self, backend: BackendKind, batches: usize) -> (DataflowReport, f64) {
        let b = self.batches(batches);
        let geom = NpeGeometry::PAPER;
        match self {
            ExecWorkload::Mlp { mlp, .. } => {
                let inputs = mlp.synth_inputs(b, 0xE8EC);
                let t0 = Instant::now();
                let report = crate::dataflow::OsEngine::tcd(geom)
                    .with_backend(backend)
                    .execute(mlp, &inputs);
                (report, t0.elapsed().as_secs_f64() * 1e3)
            }
            ExecWorkload::Cnn { cnn, .. } => {
                let inputs = cnn.synth_inputs(b, 0xE8EC);
                let t0 = Instant::now();
                let report = crate::conv::CnnEngine::tcd(geom)
                    .with_backend(backend)
                    .execute(cnn, &inputs);
                (report, t0.elapsed().as_secs_f64() * 1e3)
            }
            ExecWorkload::Graph { graph, .. } => {
                let inputs = graph.synth_inputs(b, 0xE8EC);
                let t0 = Instant::now();
                let report = crate::graph::GraphEngine::tcd(geom)
                    .with_backend(backend)
                    .execute(graph, &inputs);
                (report, t0.elapsed().as_secs_f64() * 1e3)
            }
        }
    }
}

/// The swept workloads: three Table-IV MLPs spanning the size range,
/// LeNet-5, and the whole DAG zoo.
pub fn exec_workloads() -> Vec<ExecWorkload> {
    let mut out = Vec::new();
    for ds in ["MNIST", "Adult", "Wine"] {
        let b = benchmark_by_name(ds).expect("Table-IV row");
        out.push(ExecWorkload::Mlp {
            name: format!("{} ({})", ds, b.topology.display()),
            mlp: QuantizedMlp::synthesize(b.topology.clone(), 0xE8EC_0),
        });
    }
    let lenet = lenet5();
    out.push(ExecWorkload::Cnn {
        name: lenet.network.to_string(),
        cnn: QuantizedCnn::synthesize(lenet.topology, 0xE8EC_1),
    });
    for g in graph_benchmarks() {
        out.push(ExecWorkload::Graph {
            name: g.network.to_string(),
            graph: QuantizedGraph::synthesize(g.graph, 0xE8EC_2),
        });
    }
    out
}

/// One (workload) measurement of the backend sweep.
#[derive(Debug, Clone)]
pub struct ExecRow {
    pub workload: String,
    pub family: &'static str,
    pub table4: bool,
    pub batches: usize,
    /// MACs per executed batch (work scale of the row).
    pub macs: u64,
    /// NPE cycles — identical across backends (asserted).
    pub cycles: u64,
    pub bitexact_ms: f64,
    pub fast_ms: f64,
    pub parallel_ms: f64,
    /// All three backends bit-identical to the Fix16 reference.
    pub bit_identical: bool,
}

impl ExecRow {
    pub fn speedup_vs_bitexact(&self) -> f64 {
        if self.parallel_ms == 0.0 {
            0.0
        } else {
            self.bitexact_ms / self.parallel_ms
        }
    }

    pub fn speedup_vs_fast(&self) -> f64 {
        if self.parallel_ms == 0.0 {
            0.0
        } else {
            self.fast_ms / self.parallel_ms
        }
    }
}

/// Measure one workload across the three backends.
pub fn exec_row(w: &ExecWorkload, batches: usize) -> ExecRow {
    let expect = w.reference(batches);
    let (bx, bx_ms) = w.execute(BackendKind::BitExact, batches);
    let (fa, fa_ms) = w.execute(BackendKind::Fast, batches);
    let (pa, pa_ms) = w.execute(BackendKind::Parallel, batches);
    assert_eq!(bx.cycles, fa.cycles, "{}: cycle model is backend-invariant", w.name());
    assert_eq!(fa.cycles, pa.cycles, "{}: cycle model is backend-invariant", w.name());
    let bit_identical =
        bx.outputs == expect && fa.outputs == expect && pa.outputs == expect;
    ExecRow {
        workload: w.name().to_string(),
        family: w.family(),
        table4: w.is_table4(),
        batches: w.batches(batches),
        macs: w.macs(batches),
        cycles: pa.cycles,
        bitexact_ms: bx_ms,
        fast_ms: fa_ms,
        parallel_ms: pa_ms,
        bit_identical,
    }
}

/// The full sweep.
pub fn exec_rows(batches: usize) -> Vec<ExecRow> {
    exec_workloads().iter().map(|w| exec_row(w, batches)).collect()
}

/// Render the sweep as a text table.
pub fn render_exec_table(rows: &[ExecRow], batches: usize) -> String {
    let mut t = TextTable::new(vec![
        "Workload",
        "Family",
        "B",
        "MACs",
        "Cycles",
        "bitexact (ms)",
        "fast (ms)",
        "parallel (ms)",
        "par/bitexact",
        "par/fast",
        "Bit-identical",
    ]);
    for r in rows {
        t.row(vec![
            r.workload.clone(),
            r.family.to_string(),
            r.batches.to_string(),
            r.macs.to_string(),
            r.cycles.to_string(),
            format!("{:.2}", r.bitexact_ms),
            format!("{:.2}", r.fast_ms),
            format!("{:.2}", r.parallel_ms),
            format!("{:.0}x", r.speedup_vs_bitexact()),
            format!("{:.1}x", r.speedup_vs_fast()),
            if r.bit_identical { "yes" } else { "NO" }.to_string(),
        ]);
    }
    format!(
        "Execution core: roll backends on the 16x8 NPE, {batches} MLP batches \
         ({} worker threads)\n{}",
        crate::exec::par::parallelism(),
        t.render()
    )
}

/// Serialize the sweep as the `BENCH_exec.json` trajectory artifact.
/// Hand-rolled JSON — the offline crate set has no serde.
pub fn exec_json(rows: &[ExecRow], batches: usize) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"exec\",\n");
    s.push_str(&format!("  \"batches\": {batches},\n"));
    s.push_str(&format!("  \"threads\": {},\n", crate::exec::par::parallelism()));
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"workload\": \"{}\", \"family\": \"{}\", \"table4\": {}, \
             \"batches\": {}, \"macs\": {}, \"cycles\": {}, \
             \"bitexact_ms\": {:.3}, \"fast_ms\": {:.3}, \"parallel_ms\": {:.3}, \
             \"speedup_vs_bitexact\": {:.1}, \"speedup_vs_fast\": {:.2}, \
             \"bit_identical\": {}}}{}\n",
            r.workload,
            r.family,
            r.table4,
            r.batches,
            r.macs,
            r.cycles,
            r.bitexact_ms,
            r.fast_ms,
            r.parallel_ms,
            r.speedup_vs_bitexact(),
            r.speedup_vs_fast(),
            r.bit_identical,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_workloads_bit_identical_across_backends() {
        // Wine + ResMLP keep the gate-level leg cheap in the unit suite;
        // the full sweep runs in the exec bench / CI job.
        let rows: Vec<ExecRow> = exec_workloads()
            .iter()
            .filter(|w| w.name().starts_with("Wine") || w.name() == "ResMLP")
            .map(|w| exec_row(w, 2))
            .collect();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.bit_identical, "{}", r.workload);
            assert!(r.cycles > 0 && r.macs > 0);
        }
    }

    #[test]
    #[cfg_attr(
        debug_assertions,
        ignore = "wall-clock ratio; asserted in release by exec_bench and the CI exec job"
    )]
    fn parallel_at_least_10x_bitexact_on_a_table4_workload() {
        // The acceptance bar, anchored to MNIST (784:700:10): the
        // host-parallel dot products must beat the gate-accurate
        // carry-save simulation by ≥10× (in practice it is 100×+; the
        // bar holds even on a single-core runner, where `parallel`
        // degrades to a serial i64 loop). Debug builds skip it — a
        // debug-profile wall-clock ratio under concurrent tests is
        // noise, and the release exec job enforces the bar for real.
        let w = exec_workloads()
            .into_iter()
            .find(|w| w.name().starts_with("MNIST"))
            .expect("MNIST row");
        let r = exec_row(&w, 2);
        assert!(r.table4);
        assert!(r.bit_identical, "MNIST bit-identical across backends");
        assert!(
            r.speedup_vs_bitexact() >= 10.0,
            "parallel {:.2}ms vs bitexact {:.2}ms ({:.1}x)",
            r.parallel_ms,
            r.bitexact_ms,
            r.speedup_vs_bitexact()
        );
    }

    #[test]
    fn every_backend_serves_identically_through_the_facade() {
        // The exec sweep proves backend bit-identity engine-to-engine;
        // this closes the loop through the serving facade: for each roll
        // backend, NpeService::builder(..).backend(b) must answer the
        // same bits the direct engine (and the Fix16 reference) produce.
        use crate::coordinator::BatcherConfig;
        use crate::serve::NpeService;
        use std::time::Duration;

        let mlp = benchmark_by_name("Wine")
            .map(|b| QuantizedMlp::synthesize(b.topology.clone(), 0xE8EC))
            .expect("Wine is in Table IV");
        let inputs = mlp.synth_inputs(3, 0x5EED);
        let expect = mlp.forward_batch(&inputs);
        for backend in BackendKind::ALL {
            let svc = NpeService::builder(mlp.clone())
                .geometry(NpeGeometry::PAPER)
                .backend(backend)
                .batcher(BatcherConfig::new(3, Duration::from_millis(5)))
                .build()
                .expect("valid config");
            for (x, want) in inputs.iter().zip(&expect) {
                let got =
                    svc.submit(x.clone()).expect("admitted").wait().expect("answered").output;
                assert_eq!(&got, want, "{} served == reference", backend.name());
            }
            svc.shutdown().expect("clean shutdown");
        }
    }

    #[test]
    fn json_and_table_are_shaped() {
        let w = exec_workloads()
            .into_iter()
            .find(|w| w.name().starts_with("Wine"))
            .unwrap();
        let rows = vec![exec_row(&w, 2)];
        let s = exec_json(&rows, 2);
        assert!(s.contains("\"bench\": \"exec\""));
        assert!(s.contains("\"speedup_vs_bitexact\""));
        assert!(s.trim_end().ends_with('}'));
        let t = render_exec_table(&rows, 2);
        assert!(t.contains("Workload"));
        assert!(t.contains("bitexact (ms)"));
    }
}
