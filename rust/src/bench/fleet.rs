//! Fleet serving bench: simulated throughput and wall-latency
//! percentiles vs device count, the cached-vs-cold mapper
//! microbenchmark, the admission-policy sweep (Block vs Reject at
//! 2× the measured saturation arrival rate), and the two-tenant
//! contention sweep (a greedy flood next to a light stream on one
//! shared registry pool) — the trajectory table future PRs track via
//! `BENCH_fleet.json`.

use crate::coordinator::{BatcherConfig, ServedModel};
use crate::fleet::{
    poisson_arrivals, run_open_loop, submit_open_loop, ControllerConfig, LoadGenConfig,
};
use crate::mapper::{Gamma, MapperTree, NpeGeometry, ScheduleCache};
use crate::model::{benchmark_by_name, benchmarks, QuantizedMlp};
use crate::obs::EventKind;
use crate::serve::{AdmissionPolicy, ModelRegistry, NpeService, ServeError};
use crate::util::TextTable;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Device counts swept by the fleet bench.
pub const FLEET_DEVICE_COUNTS: [usize; 4] = [1, 2, 4, 8];

/// One (device count) measurement of the loadgen bench.
#[derive(Debug, Clone)]
pub struct FleetRow {
    pub devices: usize,
    pub requests: u64,
    /// Requests answered within the collection timeout (must equal
    /// `requests` — asserted by the tests).
    pub answered: u64,
    /// Answered requests over the simulated makespan (busiest device).
    pub sim_throughput_rps: f64,
    pub sim_makespan_us: f64,
    pub wall_p50_us: f64,
    pub wall_p95_us: f64,
    pub wall_p99_us: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    pub cache_hit_rate: f64,
    pub queue_peak: u64,
}

fn iris_mlp() -> QuantizedMlp {
    let bench = benchmark_by_name("Iris").expect("Iris is in Table IV");
    QuantizedMlp::synthesize(bench.topology.clone(), 0xF1EE7)
}

fn iris_model() -> ServedModel {
    ServedModel::Mlp(iris_mlp())
}

/// Run the seeded open-loop load through a fleet of `devices` PAPER-
/// geometry NPEs serving the Iris MLP (small enough that the bench runs
/// in seconds, deep enough to exercise batching and the cache).
pub fn fleet_row(devices: usize, load: &LoadGenConfig) -> FleetRow {
    let model = iris_model();
    let arrivals = poisson_arrivals(&model, load);
    let service = NpeService::builder(model)
        .devices(vec![NpeGeometry::PAPER; devices])
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .build()
        .expect("valid fleet config");
    let responses = run_open_loop(&service, &arrivals, Duration::from_secs(60));
    let answered = responses.iter().filter(|o| o.is_some()).count() as u64;
    // Read through the service, not the raw handle: cache counters are
    // overlaid from the shared schedule cache at metrics-read time.
    let m = service.metrics();
    service.shutdown().expect("fleet service shutdown");
    FleetRow {
        devices,
        requests: load.requests as u64,
        answered,
        sim_throughput_rps: m.sim_throughput_rps(),
        sim_makespan_us: m.sim_makespan_ns() / 1e3,
        wall_p50_us: m.p50_us(),
        wall_p95_us: m.p95_us(),
        wall_p99_us: m.p99_us(),
        cache_hits: m.cache_hits,
        cache_misses: m.cache_misses,
        cache_hit_rate: m.cache_hit_rate(),
        queue_peak: m.queue_peak,
    }
}

/// The full device-count sweep.
pub fn fleet_rows(load: &LoadGenConfig) -> Vec<FleetRow> {
    FLEET_DEVICE_COUNTS
        .iter()
        .map(|&n| fleet_row(n, load))
        .collect()
}

/// One admission-policy measurement at an overload arrival rate.
#[derive(Debug, Clone)]
pub struct AdmissionRow {
    /// Policy label (`block` / `reject`).
    pub policy: &'static str,
    /// Offered open-loop arrival rate, req/s (2× measured saturation).
    pub offered_rps: f64,
    pub requests: u64,
    /// Requests that got an answer.
    pub answered: u64,
    /// Requests refused at submit or shed from the queue.
    pub shed: u64,
    /// shed / requests.
    pub shed_rate: f64,
    /// p99 wall latency over the *answered* requests, µs.
    pub wall_p99_us: f64,
}

/// Measure the wall-clock saturation throughput of a 1-device fleet:
/// requests answered over the wall time of a closed submit-then-drain
/// run. The admission sweep offers 2× this.
fn saturation_rps(load: &LoadGenConfig) -> f64 {
    let mlp = iris_mlp();
    let service = NpeService::builder(mlp.clone())
        .devices([NpeGeometry::PAPER])
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .build()
        .expect("valid calibration config");
    let n = (load.requests / 2).max(32);
    let inputs = mlp.synth_inputs(n, load.seed ^ 0xCA11);
    let t0 = Instant::now();
    let tickets: Vec<_> = inputs
        .into_iter()
        .filter_map(|x| service.submit(x).ok())
        .collect();
    for t in &tickets {
        let _ = t.wait_timeout(Duration::from_secs(60));
    }
    let elapsed = t0.elapsed().as_secs_f64();
    service.shutdown().expect("calibration shutdown");
    if elapsed > 0.0 {
        tickets.len() as f64 / elapsed
    } else {
        1e6
    }
}

/// Drive the seeded Poisson stream at `rate` through a 1-device fleet
/// under `policy`, counting sheds at both the submit gate and the queue.
fn admission_row(
    policy: AdmissionPolicy,
    rate: f64,
    load: &LoadGenConfig,
) -> AdmissionRow {
    let model = iris_model();
    let overload = LoadGenConfig { rate_rps: rate, ..*load };
    let arrivals = poisson_arrivals(&model, &overload);
    let service = NpeService::builder(model)
        .devices([NpeGeometry::PAPER])
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .admission(policy)
        .build()
        .expect("valid admission config");
    let mut answered = 0u64;
    let mut refused = 0u64;
    let mut queue_shed = 0u64;
    let mut tickets = Vec::with_capacity(arrivals.len());
    for outcome in submit_open_loop(&service, &arrivals) {
        match outcome {
            Ok(t) => tickets.push(t),
            Err(ServeError::QueueFull { .. }) => refused += 1,
            Err(_) => {}
        }
    }
    for t in tickets {
        match t.wait_timeout(Duration::from_secs(60)) {
            Ok(_) => answered += 1,
            Err(ServeError::QueueFull { .. }) => queue_shed += 1,
            Err(_) => {}
        }
    }
    let metrics = service.metrics_handle();
    service.shutdown().expect("admission bench shutdown");
    let m = metrics.lock().expect("bench metrics lock").clone();
    let shed = refused + queue_shed;
    AdmissionRow {
        policy: policy.name(),
        offered_rps: rate,
        requests: overload.requests as u64,
        answered,
        shed,
        shed_rate: shed as f64 / overload.requests.max(1) as f64,
        wall_p99_us: m.p99_us(),
    }
}

/// The admission sweep: Block vs Reject on a 1-device fleet at 2× the
/// measured saturation arrival rate (the overload regime where the
/// policies actually diverge).
pub fn admission_rows(load: &LoadGenConfig) -> Vec<AdmissionRow> {
    let rate = 2.0 * saturation_rps(load).max(500.0);
    // Reject bound: roughly two batches of headroom — deep enough to
    // ride out batching jitter, shallow enough to actually shed at 2×.
    let policies = [AdmissionPolicy::Block, AdmissionPolicy::Reject { max_depth: 16 }];
    policies.iter().map(|&p| admission_row(p, rate, load)).collect()
}

/// Cached-vs-cold Algorithm-1 timing over the whole Table-IV Γ set.
#[derive(Debug, Clone)]
pub struct MapperCacheBench {
    /// Distinct Γ problems scheduled per iteration.
    pub shapes: usize,
    /// Wall time per iteration with a fresh mapper every time, µs.
    pub cold_us: f64,
    /// Wall time per iteration through a warm [`ScheduleCache`], µs.
    pub cached_us: f64,
}

impl MapperCacheBench {
    pub fn speedup(&self) -> f64 {
        if self.cached_us == 0.0 {
            0.0
        } else {
            self.cold_us / self.cached_us
        }
    }
}

/// Measure Algorithm 1 cold (fresh `MapperTree` per iteration, the
/// pre-cache serving behaviour) vs warm-cache lookups, over every layer
/// transition of the Table-IV zoo at B = 8.
pub fn mapper_cache_bench(iters: usize) -> MapperCacheBench {
    let mut gammas: Vec<Gamma> = Vec::new();
    for b in benchmarks() {
        for (i, u) in b.topology.transitions() {
            gammas.push(Gamma::new(8, i, u));
        }
    }

    let t0 = Instant::now();
    for _ in 0..iters.max(1) {
        let mut mapper = MapperTree::new(NpeGeometry::PAPER);
        for g in &gammas {
            std::hint::black_box(mapper.schedule_layer(*g));
        }
    }
    let cold_us = t0.elapsed().as_secs_f64() * 1e6 / iters.max(1) as f64;

    let cache = ScheduleCache::new();
    let mut mapper = MapperTree::new(NpeGeometry::PAPER);
    for g in &gammas {
        std::hint::black_box(cache.get_or_compute(&mut mapper, *g));
    }
    let t1 = Instant::now();
    for _ in 0..iters.max(1) {
        for g in &gammas {
            std::hint::black_box(cache.get_or_compute(&mut mapper, *g));
        }
    }
    let cached_us = t1.elapsed().as_secs_f64() * 1e6 / iters.max(1) as f64;

    MapperCacheBench { shapes: gammas.len(), cold_us, cached_us }
}

/// Devices in the shared pool of the tenant-contention sweep.
pub const TENANT_POOL_DEVICES: usize = 4;

/// One tenant's measurement from the shared-pool contention sweep: a
/// greedy flood tenant and a light latency tenant serving same-topology
/// models through one [`ModelRegistry`] pool, concurrently.
#[derive(Debug, Clone)]
pub struct TenantRow {
    /// Scenario label — the greedy tenant's admission policy
    /// (`block` / `reject`).
    pub scenario: &'static str,
    /// Tenant name (`greedy` / `light`).
    pub tenant: &'static str,
    /// This tenant's own admission policy.
    pub policy: &'static str,
    pub requests: u64,
    pub answered: u64,
    /// Requests refused at this tenant's submit gate.
    pub shed: u64,
    pub wall_p50_us: f64,
    pub wall_p95_us: f64,
    pub wall_p99_us: f64,
    /// Shared-cache counters at scenario end. The cache is pool-wide —
    /// sharing the Algorithm-1 memo across tenants is the point — so
    /// these aggregate both tenants' lookups and repeat across a
    /// scenario's rows.
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// Submit a pre-generated arrival stream open-loop and wait everything
/// out, counting `(answered, shed)`.
fn drive_tenant(service: &NpeService, arrivals: &[crate::fleet::Arrival]) -> (u64, u64) {
    let mut answered = 0u64;
    let mut shed = 0u64;
    let mut tickets = Vec::with_capacity(arrivals.len());
    for outcome in submit_open_loop(service, arrivals) {
        match outcome {
            Ok(t) => tickets.push(t),
            Err(ServeError::QueueFull { .. }) => shed += 1,
            Err(_) => {}
        }
    }
    for t in tickets {
        match t.wait_timeout(Duration::from_secs(60)) {
            Ok(_) => answered += 1,
            Err(ServeError::QueueFull { .. }) => shed += 1,
            Err(_) => {}
        }
    }
    (answered, shed)
}

/// One contention scenario: `greedy` floods the shared pool (open-loop
/// at 1e6 req/s) under `greedy_policy` while `light` trickles in a
/// quarter of the load at the configured rate under `Block`. Both
/// tenants serve the Iris topology (different weight seeds), so every
/// Γ either maps is a shared-cache hit for the other.
fn tenant_contention_scenario(
    greedy_policy: AdmissionPolicy,
    load: &LoadGenConfig,
) -> Vec<TenantRow> {
    let iris_topology = benchmark_by_name("Iris").expect("Iris is in Table IV").topology.clone();
    let light_mlp = QuantizedMlp::synthesize(iris_topology, 0x11647);
    let registry = ModelRegistry::builder()
        .devices(vec![NpeGeometry::PAPER; TENANT_POOL_DEVICES])
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .register_with("greedy", iris_model(), greedy_policy)
        .register("light", light_mlp.clone())
        .build()
        .expect("valid registry config");

    let greedy_arrivals =
        poisson_arrivals(&iris_model(), &LoadGenConfig { rate_rps: 1e6, ..*load });
    let light_load = LoadGenConfig {
        seed: load.seed ^ 0x1164,
        rate_rps: load.rate_rps,
        requests: (load.requests / 4).max(16),
    };
    let light_arrivals = poisson_arrivals(&ServedModel::Mlp(light_mlp), &light_load);

    let greedy_svc = registry.service("greedy").expect("registered");
    let light_svc = registry.service("light").expect("registered");
    let ((g_answered, g_shed), (l_answered, l_shed)) = std::thread::scope(|s| {
        let g = s.spawn(|| drive_tenant(greedy_svc, &greedy_arrivals));
        let l = s.spawn(|| drive_tenant(light_svc, &light_arrivals));
        (g.join().expect("greedy driver"), l.join().expect("light driver"))
    });

    let scenario = greedy_policy.name();
    let gm = registry.metrics("greedy").expect("registered");
    let lm = registry.metrics("light").expect("registered");
    let rows = vec![
        TenantRow {
            scenario,
            tenant: "greedy",
            policy: greedy_policy.name(),
            requests: greedy_arrivals.len() as u64,
            answered: g_answered,
            shed: g_shed,
            wall_p50_us: gm.p50_us(),
            wall_p95_us: gm.p95_us(),
            wall_p99_us: gm.p99_us(),
            cache_hits: gm.cache_hits,
            cache_misses: gm.cache_misses,
        },
        TenantRow {
            scenario,
            tenant: "light",
            policy: AdmissionPolicy::Block.name(),
            requests: light_arrivals.len() as u64,
            answered: l_answered,
            shed: l_shed,
            wall_p50_us: lm.p50_us(),
            wall_p95_us: lm.p95_us(),
            wall_p99_us: lm.p99_us(),
            cache_hits: lm.cache_hits,
            cache_misses: lm.cache_misses,
        },
    ];
    registry.shutdown().expect("registry shutdown");
    rows
}

/// The tenant-contention sweep: the greedy tenant under `Block` (its
/// backlog queues behind the shared pool) vs under `Reject { 16 }` (the
/// flood is clipped at its own submit gate), with the light tenant's
/// per-tenant percentiles showing what each policy costs the *other*
/// tenant. Four rows: 2 scenarios × 2 tenants.
pub fn tenant_rows(load: &LoadGenConfig) -> Vec<TenantRow> {
    let mut rows = tenant_contention_scenario(AdmissionPolicy::Block, load);
    rows.extend(tenant_contention_scenario(
        AdmissionPolicy::Reject { max_depth: 16 },
        load,
    ));
    rows
}

/// Elastic sweep bounds: the pool starts (and must settle back) at
/// `ELASTIC_MIN_DEVICES` and may grow to `ELASTIC_MAX_DEVICES`.
pub const ELASTIC_MIN_DEVICES: usize = 1;
pub const ELASTIC_MAX_DEVICES: usize = 4;

/// One scenario of the elastic load-step sweep: the same burst driven
/// through a fixed pool of `ELASTIC_MIN_DEVICES` devices (the baseline
/// an elastic pool must beat) and through an elastic pool the
/// [`PoolController`](crate::fleet::PoolController) resizes live.
#[derive(Debug, Clone)]
pub struct ElasticRow {
    /// Scenario label (`fixed-min` / `elastic`).
    pub scenario: &'static str,
    pub requests: u64,
    pub answered: u64,
    pub wall_p50_us: f64,
    pub wall_p99_us: f64,
    /// Most devices live at any point during the run (sampled).
    pub peak_devices: usize,
    /// Devices live after the post-burst settle window.
    pub settled_devices: usize,
    /// `PoolResize` journal entries recorded over the run.
    pub resize_events: u64,
}

/// The fixed-size baseline: the burst through `ELASTIC_MIN_DEVICES`
/// devices, no controller.
fn elastic_baseline_row(load: &LoadGenConfig) -> ElasticRow {
    let model = iris_model();
    let arrivals = poisson_arrivals(&model, load);
    let service = NpeService::builder(model)
        .devices(vec![NpeGeometry::PAPER; ELASTIC_MIN_DEVICES])
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .build()
        .expect("valid baseline config");
    let responses = run_open_loop(&service, &arrivals, Duration::from_secs(60));
    let answered = responses.iter().filter(|o| o.is_some()).count() as u64;
    let m = service.metrics();
    service.shutdown().expect("baseline shutdown");
    ElasticRow {
        scenario: "fixed-min",
        requests: arrivals.len() as u64,
        answered,
        wall_p50_us: m.p50_us(),
        wall_p99_us: m.p99_us(),
        peak_devices: ELASTIC_MIN_DEVICES,
        settled_devices: ELASTIC_MIN_DEVICES,
        resize_events: 0,
    }
}

/// The elastic scenario: the same burst, but the controller may grow
/// the pool to `ELASTIC_MAX_DEVICES` while the backlog is deep and must
/// shrink it back to `ELASTIC_MIN_DEVICES` once the burst drains. A
/// sampling thread records the peak live-device count; every resize is
/// read back out of the event journal.
fn elastic_controller_row(load: &LoadGenConfig) -> ElasticRow {
    let model = iris_model();
    let arrivals = poisson_arrivals(&model, load);
    // Fast cadence so the sweep settles in milliseconds, not the
    // serving-grade defaults: grow as soon as the backlog exceeds 4
    // requests per device, shrink after 3 fully-idle ticks.
    let cfg = ControllerConfig::default()
        .with_period(Duration::from_millis(2))
        .with_cooldown(Duration::from_millis(10))
        .with_scale_down_idle_ticks(3);
    let service = NpeService::builder(model)
        .devices(vec![NpeGeometry::PAPER; ELASTIC_MIN_DEVICES])
        .elastic(ELASTIC_MIN_DEVICES, ELASTIC_MAX_DEVICES)
        .controller(cfg)
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .journaling(4096)
        .build()
        .expect("valid elastic config");
    let ctl = service.controller().expect("elastic service has a controller");
    let stop = Arc::new(AtomicBool::new(false));
    let monitor = {
        let ctl = Arc::clone(&ctl);
        let stop = Arc::clone(&stop);
        std::thread::spawn(move || {
            let mut peak = ctl.pool_size();
            while !stop.load(Ordering::Relaxed) {
                peak = peak.max(ctl.pool_size());
                std::thread::sleep(Duration::from_millis(1));
            }
            peak
        })
    };
    let responses = run_open_loop(&service, &arrivals, Duration::from_secs(60));
    let answered = responses.iter().filter(|o| o.is_some()).count() as u64;
    // Give the controller its idle ticks + cooldowns to reclaim the
    // burst capacity; the sweep asserts it actually gets back to min.
    let settle_deadline = Instant::now() + Duration::from_secs(10);
    while ctl.pool_size() > ELASTIC_MIN_DEVICES && Instant::now() < settle_deadline {
        std::thread::sleep(Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);
    let peak_devices = monitor.join().expect("pool-size monitor");
    let settled_devices = ctl.pool_size();
    let resize_events = service
        .journal()
        .map(|j| j.events().iter().filter(|e| e.kind == EventKind::PoolResize).count() as u64)
        .unwrap_or(0);
    let m = service.metrics();
    service.shutdown().expect("elastic shutdown");
    ElasticRow {
        scenario: "elastic",
        requests: arrivals.len() as u64,
        answered,
        wall_p50_us: m.p50_us(),
        wall_p99_us: m.p99_us(),
        peak_devices: peak_devices.max(settled_devices),
        settled_devices,
        resize_events,
    }
}

/// The elastic load-step sweep: fixed-min baseline, then the elastic
/// pool under the identical seeded burst.
pub fn elastic_rows(load: &LoadGenConfig) -> Vec<ElasticRow> {
    vec![elastic_baseline_row(load), elastic_controller_row(load)]
}

/// Render the device-count sweep as a text table.
pub fn render_fleet_table(rows: &[FleetRow], load: &LoadGenConfig) -> String {
    let mut t = TextTable::new(vec![
        "Devices",
        "Answered",
        "Sim req/s",
        "Makespan (us)",
        "p50 (us)",
        "p95 (us)",
        "p99 (us)",
        "Cache h/m",
        "Hit rate",
        "Queue peak",
    ]);
    let base = rows.first().map(|r| r.sim_throughput_rps).unwrap_or(0.0);
    for r in rows {
        t.row(vec![
            format!(
                "{}{}",
                r.devices,
                if base > 0.0 {
                    format!(" ({:.2}x)", r.sim_throughput_rps / base)
                } else {
                    String::new()
                }
            ),
            format!("{}/{}", r.answered, r.requests),
            format!("{:.0}", r.sim_throughput_rps),
            format!("{:.1}", r.sim_makespan_us),
            format!("{:.0}", r.wall_p50_us),
            format!("{:.0}", r.wall_p95_us),
            format!("{:.0}", r.wall_p99_us),
            format!("{}/{}", r.cache_hits, r.cache_misses),
            format!("{:.1}%", r.cache_hit_rate * 100.0),
            r.queue_peak.to_string(),
        ]);
    }
    format!(
        "Fleet serving the Iris MLP on 16x8 NPEs — {} Poisson requests at {:.0} req/s (seed {:#x})\n{}",
        load.requests, load.rate_rps, load.seed, t.render()
    )
}

/// Render the admission sweep as a text table.
pub fn render_admission_table(rows: &[AdmissionRow]) -> String {
    let mut t = TextTable::new(vec![
        "Policy",
        "Offered req/s",
        "Answered",
        "Shed",
        "Shed rate",
        "p99 (us)",
    ]);
    for r in rows {
        t.row(vec![
            r.policy.to_string(),
            format!("{:.0}", r.offered_rps),
            format!("{}/{}", r.answered, r.requests),
            r.shed.to_string(),
            format!("{:.1}%", r.shed_rate * 100.0),
            format!("{:.0}", r.wall_p99_us),
        ]);
    }
    format!(
        "Admission policies on a 1-device fleet at 2x saturation (open-loop Poisson)\n{}",
        t.render()
    )
}

/// Render the elastic load-step sweep as a text table.
pub fn render_elastic_table(rows: &[ElasticRow]) -> String {
    let mut t = TextTable::new(vec![
        "Scenario",
        "Answered",
        "p50 (us)",
        "p99 (us)",
        "Peak devices",
        "Settled",
        "Resizes",
    ]);
    for r in rows {
        t.row(vec![
            r.scenario.to_string(),
            format!("{}/{}", r.answered, r.requests),
            format!("{:.0}", r.wall_p50_us),
            format!("{:.0}", r.wall_p99_us),
            r.peak_devices.to_string(),
            r.settled_devices.to_string(),
            r.resize_events.to_string(),
        ]);
    }
    format!(
        "Elastic pool under a load step — bounds [{ELASTIC_MIN_DEVICES}, \
         {ELASTIC_MAX_DEVICES}], fixed-min baseline vs controller-resized pool\n{}",
        t.render()
    )
}

/// Render the tenant-contention sweep as a text table.
pub fn render_tenant_table(rows: &[TenantRow]) -> String {
    let mut t = TextTable::new(vec![
        "Scenario",
        "Tenant",
        "Policy",
        "Answered",
        "Shed",
        "p50 (us)",
        "p95 (us)",
        "p99 (us)",
        "Cache h/m",
    ]);
    for r in rows {
        t.row(vec![
            r.scenario.to_string(),
            r.tenant.to_string(),
            r.policy.to_string(),
            format!("{}/{}", r.answered, r.requests),
            r.shed.to_string(),
            format!("{:.0}", r.wall_p50_us),
            format!("{:.0}", r.wall_p95_us),
            format!("{:.0}", r.wall_p99_us),
            format!("{}/{}", r.cache_hits, r.cache_misses),
        ]);
    }
    format!(
        "Two tenants on one shared {TENANT_POOL_DEVICES}-device registry pool \
         (greedy flood vs light stream, scenario = greedy tenant's policy)\n{}",
        t.render()
    )
}

/// Serialize the sweeps (plus the mapper microbench) as the
/// `BENCH_fleet.json` trajectory artifact. Hand-rolled JSON — the
/// offline crate set has no serde.
pub fn fleet_json(
    rows: &[FleetRow],
    admission: &[AdmissionRow],
    tenants: &[TenantRow],
    elastic: &[ElasticRow],
    mapper: &MapperCacheBench,
    load: &LoadGenConfig,
) -> String {
    let mut s = String::from("{\n");
    s.push_str("  \"bench\": \"fleet\",\n");
    s.push_str(&format!(
        "  \"load\": {{\"seed\": {}, \"rate_rps\": {:.1}, \"requests\": {}}},\n",
        load.seed, load.rate_rps, load.requests
    ));
    s.push_str(&format!(
        "  \"mapper_cache\": {{\"shapes\": {}, \"cold_us\": {:.3}, \"cached_us\": {:.3}, \"speedup\": {:.1}}},\n",
        mapper.shapes,
        mapper.cold_us,
        mapper.cached_us,
        mapper.speedup()
    ));
    s.push_str("  \"admission\": [\n");
    for (i, r) in admission.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"policy\": \"{}\", \"offered_rps\": {:.1}, \"requests\": {}, \
             \"answered\": {}, \"shed\": {}, \"shed_rate\": {:.4}, \"wall_p99_us\": {:.1}}}{}\n",
            r.policy,
            r.offered_rps,
            r.requests,
            r.answered,
            r.shed,
            r.shed_rate,
            r.wall_p99_us,
            if i + 1 < admission.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"tenants\": [\n");
    for (i, r) in tenants.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"tenant\": \"{}\", \"policy\": \"{}\", \
             \"requests\": {}, \"answered\": {}, \"shed\": {}, \
             \"wall_p50_us\": {:.1}, \"wall_p95_us\": {:.1}, \"wall_p99_us\": {:.1}, \
             \"cache_hits\": {}, \"cache_misses\": {}}}{}\n",
            r.scenario,
            r.tenant,
            r.policy,
            r.requests,
            r.answered,
            r.shed,
            r.wall_p50_us,
            r.wall_p95_us,
            r.wall_p99_us,
            r.cache_hits,
            r.cache_misses,
            if i + 1 < tenants.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"elastic\": [\n");
    for (i, r) in elastic.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"requests\": {}, \"answered\": {}, \
             \"wall_p50_us\": {:.1}, \"wall_p99_us\": {:.1}, \"peak_devices\": {}, \
             \"settled_devices\": {}, \"resize_events\": {}}}{}\n",
            r.scenario,
            r.requests,
            r.answered,
            r.wall_p50_us,
            r.wall_p99_us,
            r.peak_devices,
            r.settled_devices,
            r.resize_events,
            if i + 1 < elastic.len() { "," } else { "" },
        ));
    }
    s.push_str("  ],\n");
    s.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"devices\": {}, \"requests\": {}, \"answered\": {}, \
             \"sim_throughput_rps\": {:.1}, \"sim_makespan_us\": {:.1}, \
             \"wall_p50_us\": {:.1}, \"wall_p95_us\": {:.1}, \"wall_p99_us\": {:.1}, \
             \"cache_hits\": {}, \"cache_misses\": {}, \"cache_hit_rate\": {:.4}, \
             \"queue_peak\": {}}}{}\n",
            r.devices,
            r.requests,
            r.answered,
            r.sim_throughput_rps,
            r.sim_makespan_us,
            r.wall_p50_us,
            r.wall_p95_us,
            r.wall_p99_us,
            r.cache_hits,
            r.cache_misses,
            r.cache_hit_rate,
            r.queue_peak,
            if i + 1 < rows.len() { "," } else { "" },
        ));
    }
    s.push_str("  ]\n}\n");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_load() -> LoadGenConfig {
        // Deep enough that the worst-case batching still clears the 90%
        // hit-rate bar: misses are bounded by 3 transitions × 8 possible
        // batch sizes = 24 keys; lookups are 3 per batch over ≥ 96
        // batches ≥ 288, so the hit rate is ≥ 1 − 24/288 ≈ 91.7% even
        // if every batch size occurs.
        LoadGenConfig { seed: 0xBE9C, rate_rps: 1e6, requests: 768 }
    }

    #[test]
    fn four_devices_at_least_double_throughput() {
        // The ISSUE acceptance bar: fleet(4) ≥ 2× fleet(1) simulated
        // throughput, nothing lost, and a ≥ 90% steady-state cache hit
        // rate in the bench run.
        let load = quick_load();
        let one = fleet_row(1, &load);
        let four = fleet_row(4, &load);
        assert_eq!(one.answered, one.requests, "no loss on 1 device");
        assert_eq!(four.answered, four.requests, "no loss on 4 devices");
        assert!(
            four.sim_throughput_rps >= 2.0 * one.sim_throughput_rps,
            "4 devices {:.0} req/s < 2x single {:.0} req/s",
            four.sim_throughput_rps,
            one.sim_throughput_rps
        );
        assert!(
            four.cache_hit_rate >= 0.9,
            "steady-state hit rate {:.2} < 0.9",
            four.cache_hit_rate
        );
        assert!(four.wall_p99_us >= four.wall_p50_us);
    }

    #[test]
    fn admission_sweep_blocks_everything_and_reject_sheds() {
        // Small but genuinely overloaded: Block answers everything (the
        // backlog just queues), Reject keeps its bound by refusing some.
        let load = LoadGenConfig { seed: 0xADA1, rate_rps: 1e6, requests: 192 };
        let rows = admission_rows(&load);
        assert_eq!(rows.len(), 2);
        let block = &rows[0];
        let reject = &rows[1];
        assert_eq!(block.policy, "block");
        assert_eq!(reject.policy, "reject");
        assert_eq!(block.answered, block.requests, "Block never sheds");
        assert_eq!(block.shed, 0);
        assert_eq!(
            reject.answered + reject.shed,
            reject.requests,
            "every request is answered or shed, never lost"
        );
        assert!(block.offered_rps > 0.0);
    }

    #[test]
    fn mapper_cache_bench_counts_shapes() {
        let b = mapper_cache_bench(2);
        // 7 Table-IV MLPs: 4 two-transition + 2 three-transition +
        // 1 four-transition topology = 18 layer problems.
        assert_eq!(b.shapes, 18);
        assert!(b.cold_us > 0.0 && b.cached_us > 0.0);
    }

    #[test]
    fn tenant_sweep_accounts_for_every_request() {
        // Small contention run: both scenarios, both tenants, every
        // request either answered or shed at the submit gate. Latency
        // bounds are deliberately not asserted (wall-clock, flaky);
        // accounting and shared-cache reuse are deterministic.
        let load = LoadGenConfig { seed: 0x7E4A, rate_rps: 5e4, requests: 96 };
        let rows = tenant_rows(&load);
        assert_eq!(rows.len(), 4, "2 scenarios x 2 tenants");
        assert_eq!(rows[0].scenario, "block");
        assert_eq!(rows[2].scenario, "reject");
        for r in &rows {
            assert_eq!(
                r.answered + r.shed,
                r.requests,
                "{}/{}: every request answered or shed, never lost",
                r.scenario,
                r.tenant
            );
        }
        // The light tenant runs under Block in both scenarios: nothing shed.
        for r in rows.iter().filter(|r| r.tenant == "light") {
            assert_eq!(r.policy, "block");
            assert_eq!(r.shed, 0, "Block tenant never sheds");
        }
        // Same topology on a shared cache: reuse must show up.
        assert!(rows[0].cache_hits > 0, "shared cache saw no hits");
        let table = render_tenant_table(&rows);
        assert!(table.contains("greedy") && table.contains("light"));
    }

    #[test]
    fn elastic_sweep_grows_under_burst_and_settles_back() {
        // The ISSUE acceptance bar: under the same seeded burst, the
        // controller grows past min (cutting tail latency below the
        // fixed-min baseline), shrinks back to min once the burst
        // drains, journals every resize, and loses nothing.
        let load = LoadGenConfig { seed: 0xE1A5, rate_rps: 1e6, requests: 768 };
        let rows = elastic_rows(&load);
        assert_eq!(rows.len(), 2);
        let (fixed, elastic) = (&rows[0], &rows[1]);
        assert_eq!(fixed.scenario, "fixed-min");
        assert_eq!(elastic.scenario, "elastic");
        assert_eq!(fixed.answered, fixed.requests, "no loss at fixed size");
        assert_eq!(elastic.answered, elastic.requests, "no loss across resizes");
        assert!(
            elastic.peak_devices > ELASTIC_MIN_DEVICES,
            "controller never grew under a {}-request burst",
            elastic.requests
        );
        assert_eq!(
            elastic.settled_devices, ELASTIC_MIN_DEVICES,
            "controller failed to reclaim burst capacity"
        );
        assert!(
            elastic.resize_events >= 2,
            "every grow and shrink must be journaled, saw {}",
            elastic.resize_events
        );
        assert!(
            elastic.wall_p99_us < fixed.wall_p99_us,
            "elastic p99 {:.0}us not below fixed-min baseline {:.0}us",
            elastic.wall_p99_us,
            fixed.wall_p99_us
        );
        let table = render_elastic_table(&rows);
        assert!(table.contains("fixed-min") && table.contains("elastic"));
    }

    #[test]
    fn json_is_shaped() {
        let load = LoadGenConfig { seed: 1, rate_rps: 2e6, requests: 16 };
        let rows = vec![fleet_row(1, &load)];
        let admission = vec![admission_row(AdmissionPolicy::Block, 1e5, &load)];
        let tenants = vec![TenantRow {
            scenario: "block",
            tenant: "greedy",
            policy: "block",
            requests: 16,
            answered: 16,
            shed: 0,
            wall_p50_us: 1.0,
            wall_p95_us: 2.0,
            wall_p99_us: 3.0,
            cache_hits: 4,
            cache_misses: 2,
        }];
        let elastic = vec![ElasticRow {
            scenario: "fixed-min",
            requests: 16,
            answered: 16,
            wall_p50_us: 1.0,
            wall_p99_us: 2.0,
            peak_devices: 1,
            settled_devices: 1,
            resize_events: 0,
        }];
        let mapper = mapper_cache_bench(1);
        let s = fleet_json(&rows, &admission, &tenants, &elastic, &mapper, &load);
        assert!(s.contains("\"bench\": \"fleet\""));
        assert!(s.contains("\"devices\": 1"));
        assert!(s.contains("\"mapper_cache\""));
        assert!(s.contains("\"admission\""));
        assert!(s.contains("\"policy\": \"block\""));
        assert!(s.contains("\"tenants\""));
        assert!(s.contains("\"tenant\": \"greedy\""));
        assert!(s.contains("\"elastic\""));
        assert!(s.contains("\"scenario\": \"fixed-min\""));
        assert!(s.trim_end().ends_with('}'));
        let table = render_fleet_table(&rows, &load);
        assert!(table.contains("Devices"));
        assert!(table.contains("Hit rate"));
        let atable = render_admission_table(&admission);
        assert!(atable.contains("Shed rate"));
    }
}
