//! Table I generator: PPA of the eight conventional MACs vs TCD-MAC,
//! printed alongside the paper's published values.

use crate::ppa::paper;
use crate::ppa::PpaReport;
use crate::tcdmac::table1_reports;
use crate::util::TextTable;

/// Measured Table-I rows (paper row order).
pub fn table1_rows() -> Vec<PpaReport> {
    table1_reports()
}

/// Render measured-vs-paper Table I.
pub fn render_table1(rows: &[PpaReport]) -> String {
    let mut t = TextTable::new(vec![
        "MAC",
        "Area(um2)",
        "Power(uW)",
        "Delay(ns)",
        "PDP(pJ)",
        "paper-Area",
        "paper-Power",
        "paper-Delay",
        "paper-PDP",
    ]);
    for (r, p) in rows.iter().zip(paper::TABLE1) {
        t.row(vec![
            r.name.to_string(),
            format!("{:.0}", r.area_um2),
            format!("{:.0}", r.power_uw),
            format!("{:.2}", r.delay_ns),
            format!("{:.2}", r.pdp_pj()),
            p.area_um2.map_or("-".into(), |a| format!("{a:.0}")),
            format!("{:.0}", p.power_uw),
            format!("{:.2}", p.delay_ns),
            format!("{:.2}", p.pdp_pj),
        ]);
    }
    // Improvement summary line (paper §IV-B claims).
    let tcd = rows.last().unwrap();
    let conv = &rows[..rows.len() - 1];
    let imp = |f: fn(&PpaReport) -> f64| {
        let lo = conv
            .iter()
            .map(|r| (1.0 - f(tcd) / f(r)) * 100.0)
            .fold(f64::INFINITY, f64::min);
        let hi = conv
            .iter()
            .map(|r| (1.0 - f(tcd) / f(r)) * 100.0)
            .fold(f64::NEG_INFINITY, f64::max);
        (lo, hi)
    };
    let (alo, ahi) = imp(|r| r.area_um2);
    let (plo, phi) = imp(|r| r.power_uw);
    let (dlo, dhi) = imp(|r| r.pdp_pj());
    format!(
        "{}\nTCD-MAC improvement vs conventional: area {:.0}%–{:.0}% (paper 23–40%), \
         power {:.0}%–{:.0}% (paper 4–31%), PDP {:.0}%–{:.0}% (paper 46–62%)\n",
        t.render(),
        alo,
        ahi,
        plo,
        phi,
        dlo,
        dhi
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nine_rows_rendered() {
        let rows = table1_rows();
        assert_eq!(rows.len(), 9);
        let s = render_table1(&rows);
        assert!(s.contains("TCD-MAC"));
        assert!(s.contains("(BRx2, KS)"));
        assert!(s.contains("improvement"));
    }
}
