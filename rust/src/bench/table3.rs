//! Table III generator: TCD-NPE implementation details and chip-level PPA.

use crate::mapper::NpeGeometry;
use crate::npe::npe_ppa;
use crate::ppa::paper::table3;
use crate::tcdmac::MacKind;
use crate::util::TextTable;

/// Render measured-vs-paper Table III.
pub fn render_table3() -> String {
    let p = npe_ppa(NpeGeometry::PAPER, MacKind::Tcd);
    let mut t = TextTable::new(vec!["Feature", "Measured", "Paper"]);
    t.row(vec!["PE-array".into(), "16 x 8".to_string(), "16 x 8".into()]);
    t.row(vec![
        "Input format".into(),
        "signed 16-bit fixed".to_string(),
        "signed 16-bit fixed".into(),
    ]);
    t.row(vec!["Dataflow".into(), "OS".to_string(), "OS".into()]);
    t.row(vec![
        "W-mem / FM-mem".into(),
        "512 KB / 2x64 KB".to_string(),
        "512 KB / 2x64 KB".into(),
    ]);
    t.row(vec![
        "PE / Mem voltage".into(),
        format!("{:.2} V / {:.2} V", table3::PE_VDD, table3::MEM_VDD),
        "0.95 V / 0.70 V".into(),
    ]);
    t.row(vec![
        "Area (mm2)".into(),
        format!("{:.2}", p.area_mm2),
        format!("{:.2}", table3::AREA_MM2),
    ]);
    t.row(vec![
        "PE-array area (mm2)".into(),
        format!("{:.3}", p.pe_array_area_mm2),
        format!("{:.3}", table3::PE_ARRAY_AREA_MM2),
    ]);
    t.row(vec![
        "Memory area (mm2)".into(),
        format!("{:.2}", p.memory_area_mm2),
        format!("{:.2}", table3::MEM_AREA_MM2),
    ]);
    t.row(vec![
        "Max frequency (MHz)".into(),
        format!("{:.0}", p.max_freq_mhz),
        format!("{:.0}", table3::MAX_FREQ_MHZ),
    ]);
    t.row(vec![
        "Overall leakage (mW)".into(),
        format!("{:.1}", p.overall_leak_mw),
        format!("{:.1}", table3::OVERALL_LEAK_MW),
    ]);
    t.row(vec![
        "PE-array leakage (mW)".into(),
        format!("{:.1}", p.pe_array_leak_mw),
        format!("{:.1}", table3::PE_ARRAY_LEAK_MW),
    ]);
    t.row(vec![
        "Memory leakage (mW)".into(),
        format!("{:.1}", p.memory_leak_mw),
        format!("{:.1}", table3::MEM_LEAK_MW),
    ]);
    t.row(vec![
        "Others leakage (mW)".into(),
        format!("{:.1}", p.others_leak_mw),
        format!("{:.1}", table3::OTHERS_LEAK_MW),
    ]);
    t.render()
}

#[cfg(test)]
mod tests {
    #[test]
    fn renders() {
        let s = super::render_table3();
        assert!(s.contains("Max frequency"));
        assert!(s.contains("636"));
    }
}
