//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! * **geometry** — PE-array aspect ratio at fixed PE count (the paper
//!   fixes 16×8 without justification; the TG structure makes the shape
//!   matter for small layers);
//! * **batch** — mapper utilization vs batch count (the multi-batch
//!   packing argument of §III-B.1);
//! * **voltage** — scaled-memory fault tolerance (§IV-C): voltage sweep ×
//!   MSB protection, accuracy vs leakage saving;
//! * **mac** — which conventional MAC the comparison NPE uses (the paper
//!   picks the "fastest and most efficient"; the gap barely moves).

use crate::dataflow::{cached_mac_ppa, DataflowEngine, OsEngine};
use crate::mapper::{MapperTree, NpeGeometry};
use crate::memory::faults::{read_ber, resilience_probe, FaultConfig};
use crate::model::{benchmark_by_name, QuantizedMlp};
use crate::ppa::VoltageDomain;
use crate::tcdmac::MacKind;
use crate::util::TextTable;

/// Geometry ablation: same 128 PEs, different TG shapes.
pub fn ablate_geometry(batches: usize) -> String {
    let shapes = [(128, 1), (64, 2), (32, 4), (16, 8), (8, 16), (4, 32), (2, 64), (1, 128)];
    let bench = benchmark_by_name("Poker Hands").unwrap();
    let mlp = QuantizedMlp::synthesize(bench.topology.clone(), 7);
    let inputs = mlp.synth_inputs(batches, 8);
    let mut t = TextTable::new(vec![
        "TGs x cols",
        "configs",
        "rolls",
        "utilization",
        "time (us)",
    ]);
    for (r, c) in shapes {
        let geom = NpeGeometry::new(r, c);
        let mut m = MapperTree::new(geom);
        let ms = m.schedule_model(&bench.topology, batches);
        let rep = OsEngine::tcd(geom).execute(&mlp, &inputs);
        t.row(vec![
            format!("{r}x{c}"),
            geom.configs().len().to_string(),
            ms.total_rolls().to_string(),
            format!("{:.0}%", ms.utilization() * 100.0),
            format!("{:.1}", rep.time_us()),
        ]);
    }
    format!("geometry ablation ({}, B={batches}):\n{}", bench.dataset, t.render())
}

/// Batch ablation: utilization and per-sample time vs batch count.
pub fn ablate_batch() -> String {
    let bench = benchmark_by_name("Iris").unwrap();
    let mlp = QuantizedMlp::synthesize(bench.topology.clone(), 7);
    let mut t = TextTable::new(vec!["B", "rolls", "utilization", "us/sample"]);
    for b in [1usize, 2, 4, 8, 16, 32, 64] {
        let inputs = mlp.synth_inputs(b, 9);
        let mut m = MapperTree::new(NpeGeometry::PAPER);
        let ms = m.schedule_model(&bench.topology, b);
        let rep = OsEngine::tcd(NpeGeometry::PAPER).execute(&mlp, &inputs);
        t.row(vec![
            b.to_string(),
            ms.total_rolls().to_string(),
            format!("{:.0}%", ms.utilization() * 100.0),
            format!("{:.3}", rep.time_us() / b as f64),
        ]);
    }
    format!("batch ablation ({}, 16x8 array):\n{}", bench.dataset, t.render())
}

/// §IV-C voltage-scaling study: BER, leakage saving, and model accuracy
/// with and without MSB protection.
pub fn ablate_voltage() -> String {
    let bench = benchmark_by_name("Wine").unwrap();
    let mlp = QuantizedMlp::synthesize(bench.topology.clone(), 3);
    let inputs = mlp.synth_inputs(64, 4);
    let mut t = TextTable::new(vec![
        "Vdd (V)",
        "read BER",
        "leak save",
        "agree (unprot.)",
        "agree (8 MSB prot.)",
    ]);
    let leak_at = |v: f64| {
        let d = VoltageDomain { vdd: v };
        d.leakage_scale()
    };
    let base_leak = leak_at(0.70);
    for vdd in [0.70, 0.65, 0.60, 0.55, 0.52, 0.50] {
        let unprot = resilience_probe(&mlp, &inputs, &FaultConfig::new(vdd, 0, 77));
        let prot = resilience_probe(&mlp, &inputs, &FaultConfig::new(vdd, 8, 77));
        t.row(vec![
            format!("{vdd:.2}"),
            format!("{:.1e}", read_ber(vdd)),
            format!("{:.0}%", (1.0 - leak_at(vdd) / base_leak) * 100.0),
            format!("{:.0}%", unprot.class_agreement * 100.0),
            format!("{:.0}%", prot.class_agreement * 100.0),
        ]);
    }
    format!(
        "voltage-scaled memory study ({}; paper §IV-C; {} samples):\n{}",
        bench.dataset,
        inputs.len(),
        t.render()
    )
}

/// Conventional-MAC choice ablation for the comparison NPE.
pub fn ablate_mac(batches: usize) -> String {
    let bench = benchmark_by_name("Adult").unwrap();
    let mlp = QuantizedMlp::synthesize(bench.topology.clone(), 5);
    let inputs = mlp.synth_inputs(batches, 6);
    let tcd = OsEngine::tcd(NpeGeometry::PAPER).execute(&mlp, &inputs);
    let mut t = TextTable::new(vec!["comparison MAC", "delay (ns)", "TCD speedup", "TCD energy x"]);
    for kind in MacKind::table1_order() {
        if kind == MacKind::Tcd {
            continue;
        }
        let rep = OsEngine::new(NpeGeometry::PAPER, kind).execute(&mlp, &inputs);
        t.row(vec![
            kind.name().to_string(),
            format!("{:.2}", cached_mac_ppa(kind).delay_ns),
            format!("{:.2}x", rep.time_ns / tcd.time_ns),
            format!(
                "{:.2}x",
                rep.energy.on_chip_pj() / tcd.energy.on_chip_pj()
            ),
        ]);
    }
    format!("conventional-MAC choice ({}, B={batches}):\n{}", bench.dataset, t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_ablation_runs() {
        let s = ablate_geometry(4);
        assert!(s.contains("16x8"));
        assert!(s.contains("1x128"));
    }

    #[test]
    fn batch_ablation_shows_amortization() {
        let s = ablate_batch();
        assert!(s.lines().count() > 7);
    }

    #[test]
    fn voltage_ablation_runs() {
        let s = ablate_voltage();
        assert!(s.contains("0.70"));
        assert!(s.contains("0.50"));
    }

    #[test]
    fn mac_ablation_all_slower_than_tcd() {
        let s = ablate_mac(4);
        // Every row's speedup is >1 (TCD wins against every baseline).
        for line in s.lines().skip(3) {
            if let Some(cell) = line.split('|').nth(3) {
                let v: f64 = cell.trim().trim_end_matches('x').parse().unwrap_or(99.0);
                assert!(v > 1.0, "{line}");
            }
        }
    }
}
