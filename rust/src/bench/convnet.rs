//! Conv-workload table: round counts, execution time and energy of the
//! im2col-lowered CNN zoo on TCD-MAC vs conventional-MAC dataflows —
//! the CNN companion to the Fig. 10 comparison.

use crate::conv::{im2col_expansion, lower_cnn, CnnEngine, QuantizedCnn};
use crate::dataflow::DataflowReport;
use crate::mapper::{MapperTree, NpeGeometry};
use crate::model::zoo::cnn_benchmarks;
use crate::util::TextTable;

/// Default batch count for the conv sweeps (same spirit as Fig. 10's
/// `FIG10_BATCHES`, kept small because conv GEMMs carry B·P rows).
pub const CONV_BATCHES: usize = 4;

/// One (CNN benchmark × MAC kind) measurement.
#[derive(Debug, Clone)]
pub struct ConvRow {
    pub network: &'static str,
    pub dataset: &'static str,
    pub report: DataflowReport,
    /// Algorithm-1 rolls across all lowered GEMMs.
    pub rolls: usize,
    /// FM-Mem read amplification of the im2col lowering.
    pub im2col_expansion: f64,
}

/// Run the CNN zoo on the TCD and best-conventional MAC dataflows.
pub fn conv_rows(batches: usize) -> Vec<ConvRow> {
    let geom = NpeGeometry::PAPER;
    let mut out = Vec::new();
    for b in cnn_benchmarks() {
        let cnn = QuantizedCnn::synthesize(b.topology.clone(), 0xC0DE);
        let inputs = cnn.synth_inputs(batches, 0xDA7A);
        // Throwaway lowering just for roll counts: the mapper DP is
        // memoized and costs microseconds, so sharing state with the
        // engines' internal trees isn't worth coupling them.
        let rolls = lower_cnn(&mut MapperTree::new(geom), &b.topology, batches).total_rolls();
        let expansion = im2col_expansion(&b.topology);
        for mut engine in [CnnEngine::tcd(geom), CnnEngine::conventional(geom)] {
            out.push(ConvRow {
                network: b.network,
                dataset: b.dataset,
                report: engine.execute(&cnn, &inputs),
                rolls,
                im2col_expansion: expansion,
            });
        }
    }
    out
}

/// Render the conv comparison as a text table (rows arrive in pairs:
/// TCD first, conventional second).
pub fn render_conv_table(rows: &[ConvRow], batches: usize) -> String {
    let mut t = TextTable::new(vec![
        "Network",
        "Dataset",
        "MAC",
        "Rolls",
        "Cycles",
        "Time (us)",
        "Energy (uJ)",
        "vs TCD",
        "im2col reads",
    ]);
    for pair in rows.chunks(2) {
        let tcd_time = pair[0].report.time_ns;
        for r in pair {
            t.row(vec![
                r.network.to_string(),
                r.dataset.to_string(),
                r.report.mac.to_string(),
                r.rolls.to_string(),
                r.report.cycles.to_string(),
                format!("{:.1}", r.report.time_us()),
                format!("{:.2}", r.report.energy_uj()),
                format!("{:.2}x", r.report.time_ns / tcd_time),
                format!("{:.1}x", r.im2col_expansion),
            ]);
        }
    }
    format!("CNN zoo on the 16x8 NPE, B={batches} (im2col lowering)\n{}", t.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcd_wins_on_every_cnn() {
        // The paper's headline must carry over to the conv workload:
        // lower time and lower energy than the conventional-MAC NPE.
        for pair in conv_rows(2).chunks(2) {
            let (tcd, conv) = (&pair[0], &pair[1]);
            assert!(tcd.report.dataflow.contains("TCD"));
            assert!(
                tcd.report.time_ns < conv.report.time_ns,
                "{}: TCD {:.0}ns vs conv {:.0}ns",
                tcd.network,
                tcd.report.time_ns,
                conv.report.time_ns
            );
            assert!(
                tcd.report.energy.total_pj() < conv.report.energy.total_pj(),
                "{}: energy",
                tcd.network
            );
            // Both kinds agree on the math.
            assert_eq!(tcd.report.outputs, conv.report.outputs);
            assert_eq!(tcd.rolls, conv.rolls);
            assert!(tcd.im2col_expansion > 1.0);
        }
    }

    #[test]
    fn render_contains_both_networks() {
        let s = render_conv_table(&conv_rows(1), 1);
        assert!(s.contains("LeNet-5"));
        assert!(s.contains("CifarNet"));
        assert!(s.contains("TCD-MAC"));
    }
}
