//! Table II generator: throughput and energy improvement of the TCD-MAC
//! over each conventional MAC for streams of 1 / 10 / 100 / 1000 MACs.
//!
//! Derivation (validated against the paper's own Table I → Table II
//! relationship): for a stream of N operations,
//!
//! * time(conv) = N · T_conv,           time(TCD) = (N+1) · T_tcd
//! * energy(conv) = N · PDP_conv,       energy(TCD) = (N+1) · PDP_tcd
//!
//! **Note (documented in EXPERIMENTS.md):** recomputing the paper's own
//! numbers from its Table I shows its Table II throughput and energy
//! column *headers* are swapped — e.g. (BRx2, KS) at N=1:
//! 1 − 2·1.57/2.85 = −10% is a *time* ratio but appears in the energy
//! column, while 1 − 2·5.02/13.31 = +25% is an *energy* ratio but appears
//! under throughput. We print the correctly-labeled values.

use super::table1::table1_rows;
use crate::ppa::PpaReport;
use crate::util::TextTable;

/// Stream sizes of Table II.
pub const STREAM_SIZES: [usize; 4] = [1, 10, 100, 1000];

/// One Table-II row: improvements (%) per stream size.
#[derive(Debug, Clone)]
pub struct Table2Row {
    pub mac: &'static str,
    pub throughput_pct: [f64; 4],
    pub energy_pct: [f64; 4],
}

/// Throughput improvement (%) of TCD vs a conventional MAC at stream N.
pub fn throughput_improvement(tcd: &PpaReport, conv: &PpaReport, n: usize) -> f64 {
    (1.0 - ((n + 1) as f64 * tcd.delay_ns) / (n as f64 * conv.delay_ns)) * 100.0
}

/// Energy improvement (%) of TCD vs a conventional MAC at stream N.
pub fn energy_improvement(tcd: &PpaReport, conv: &PpaReport, n: usize) -> f64 {
    (1.0 - ((n + 1) as f64 * tcd.pdp_pj()) / (n as f64 * conv.pdp_pj())) * 100.0
}

/// Compute all Table-II rows from the measured Table-I reports.
pub fn table2_rows() -> Vec<Table2Row> {
    let rows = table1_rows();
    let tcd = *rows.last().unwrap();
    rows[..rows.len() - 1]
        .iter()
        .map(|conv| {
            let mut th = [0.0; 4];
            let mut en = [0.0; 4];
            for (i, n) in STREAM_SIZES.iter().enumerate() {
                th[i] = throughput_improvement(&tcd, conv, *n);
                en[i] = energy_improvement(&tcd, conv, *n);
            }
            Table2Row { mac: conv.name, throughput_pct: th, energy_pct: en }
        })
        .collect()
}

/// Render Table II.
pub fn render_table2(rows: &[Table2Row]) -> String {
    let mut t = TextTable::new(vec![
        "MAC", "thr@1", "thr@10", "thr@100", "thr@1000", "en@1", "en@10", "en@100", "en@1000",
    ]);
    for r in rows {
        let mut cells = vec![r.mac.to_string()];
        cells.extend(r.throughput_pct.iter().map(|v| format!("{v:.0}")));
        cells.extend(r.energy_pct.iter().map(|v| format!("{v:.0}")));
        t.row(cells);
    }
    t.render()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ppa::paper;

    #[test]
    fn paper_table2_derivation_confirms_swapped_headers() {
        // Using the paper's own Table-I values: (BRx2, KS) at N = 1.
        let tcd = paper::TABLE1.last().unwrap();
        let conv = &paper::TABLE1[0];
        let time_ratio = (1.0 - 2.0 * tcd.delay_ns / conv.delay_ns) * 100.0;
        let energy_ratio = (1.0 - 2.0 * tcd.pdp_pj / conv.pdp_pj) * 100.0;
        // Paper prints 25 under "throughput" and −10 under "energy";
        // the actual time ratio is −10 and the actual energy ratio is 25.
        assert!((time_ratio - -10.2).abs() < 1.0, "{time_ratio}");
        assert!((energy_ratio - 24.6).abs() < 1.0, "{energy_ratio}");
    }

    #[test]
    fn improvements_grow_with_stream_length() {
        for r in table2_rows() {
            assert!(r.throughput_pct[3] > r.throughput_pct[0], "{}", r.mac);
            assert!(r.energy_pct[3] > r.energy_pct[0], "{}", r.mac);
            // Long streams amortize the extra cycle: both must be positive
            // by N = 100 (paper: 41–63%).
            assert!(r.throughput_pct[2] > 0.0);
            assert!(r.energy_pct[2] > 0.0);
        }
    }

    #[test]
    fn long_stream_bands_match_paper_shape() {
        // Paper Table II @1000 (labels corrected): time 37–54%,
        // energy 47–63%. Accept ±15pp bands on our substrate.
        for r in table2_rows() {
            assert!(
                r.throughput_pct[3] > 22.0 && r.throughput_pct[3] < 69.0,
                "{}: {:.0}",
                r.mac,
                r.throughput_pct[3]
            );
            assert!(
                r.energy_pct[3] > 32.0 && r.energy_pct[3] < 78.0,
                "{}: {:.0}",
                r.mac,
                r.energy_pct[3]
            );
        }
    }

    #[test]
    fn render_has_all_macs() {
        let s = render_table2(&table2_rows());
        assert_eq!(s.lines().count(), 2 + 8);
    }
}
