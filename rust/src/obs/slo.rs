//! Per-tenant SLO tracking: a latency objective (`p ≤ N µs`) plus a
//! target fraction (`… for ≥ 99 % of requests`), evaluated against the
//! serving layer's existing [`LogHistogram`] wall-latency lanes.
//!
//! Nothing new is recorded on the hot path — the tracker is a pure
//! *view* over counts the coordinator already keeps. `good` is
//! [`LogHistogram::count_le`] at the objective, `bad` is the rest, and
//! the error-budget burn rate is the observed bad fraction over the
//! allowed bad fraction (`1 − target`): burn `< 1` means latency is
//! inside budget, `1` exactly on it, `> 1` burning reserve.
//!
//! **Objective rounding.** The histogram is log-bucketed, so it cannot
//! distinguish latencies inside one bucket; `count_le` is only exact at
//! bucket *tops*. [`SloConfig::new`] therefore snaps the objective **up**
//! to the top of its enclosing bucket once, at construction
//! ([`LogHistogram::bucket_top`]), and every evaluation compares against
//! that snapped bound — exact by construction, never data-dependent.
//! The snap widens the objective by at most one sub-bucket (≤ ~3 %);
//! before it existed, an off-boundary objective could *under*-count good
//! events (`count_le`'s min-clamp zeroed the count when the raw
//! objective fell below the smallest sample even though that sample
//! shared the objective's bucket) or silently over-count by the partial
//! bucket. [`SloConfig::objective_ns`] exposes the effective bound.
//!
//! The only state is a latch: [`SloTracker`] remembers whether it last
//! saw the budget exhausted, so the caller can journal the *transition*
//! (one `SloBudgetExhausted` event per excursion, re-armed on
//! recovery) instead of spamming every evaluation.

use super::LogHistogram;
use std::sync::atomic::{AtomicBool, Ordering};

/// A latency SLO: at least `target` of requests answer within
/// `objective_us`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloConfig {
    /// Wall-latency objective, µs (submit → response), as configured.
    pub objective_us: u64,
    /// Required fraction of requests inside the objective, in (0, 1].
    pub target: f64,
    /// The *effective* objective in ns: `objective_us · 1000` snapped up
    /// to its enclosing histogram bucket top, so `count_le` is exact (see
    /// the module docs on objective rounding).
    objective_ns: u64,
}

impl SloConfig {
    /// `target` is clamped into (0, 1] — a nonsensical target would
    /// otherwise make every burn-rate division meaningless. The
    /// objective is snapped up to the top of its enclosing histogram
    /// bucket (≤ ~3 % widening) so every later evaluation is exact.
    pub fn new(objective_us: u64, target: f64) -> Self {
        let objective_ns = LogHistogram::bucket_top(objective_us.saturating_mul(1_000));
        Self { objective_us, target: target.clamp(f64::MIN_POSITIVE, 1.0), objective_ns }
    }

    /// The effective (bucket-top-snapped) objective in ns that
    /// evaluations compare latencies against.
    pub fn objective_ns(&self) -> u64 {
        self.objective_ns
    }
}

/// One evaluation of an SLO against a latency histogram.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloStatus {
    pub objective_us: u64,
    pub target: f64,
    /// Requests answered within the objective.
    pub good: u64,
    /// Requests answered outside the objective.
    pub bad: u64,
    /// Observed good fraction (`1.0` before any request answers — an
    /// empty window has broken no promise).
    pub compliance: f64,
    /// Observed bad fraction over the allowed bad fraction
    /// (`1 − target`). `< 1` inside budget, `≥ 1` exhausted;
    /// `+∞` when `target == 1.0` and anything at all was slow.
    pub burn_rate: f64,
}

impl SloStatus {
    pub fn total(&self) -> u64 {
        self.good + self.bad
    }

    /// Budget exhausted: the error budget is fully consumed (or worse).
    pub fn exhausted(&self) -> bool {
        self.burn_rate >= 1.0
    }

    /// One-line log form, e.g. `p<=200us@99%: 99.7% good, burn 0.30`.
    pub fn render(&self) -> String {
        format!(
            "p<={}us@{:.0}%: {:.1}% good, burn {:.2}",
            self.objective_us,
            self.target * 100.0,
            self.compliance * 100.0,
            self.burn_rate,
        )
    }
}

/// Evaluates an [`SloConfig`] against latency histograms and latches
/// budget-exhaustion transitions.
#[derive(Debug)]
pub struct SloTracker {
    config: SloConfig,
    /// Latched "last seen exhausted" — lets `track` report only the
    /// *edge* into exhaustion.
    exhausted: AtomicBool,
}

impl SloTracker {
    pub fn new(config: SloConfig) -> Self {
        Self { config, exhausted: AtomicBool::new(false) }
    }

    pub fn config(&self) -> SloConfig {
        self.config
    }

    /// Pure evaluation: no state is touched.
    pub fn evaluate(&self, latencies: &LogHistogram) -> SloStatus {
        let total = latencies.count();
        let good = latencies.count_le(self.config.objective_ns);
        let bad = total - good;
        let compliance = if total == 0 { 1.0 } else { good as f64 / total as f64 };
        let allowed = 1.0 - self.config.target;
        let burn_rate = if total == 0 {
            0.0
        } else if allowed <= 0.0 {
            if bad == 0 {
                0.0
            } else {
                f64::INFINITY
            }
        } else {
            (bad as f64 / total as f64) / allowed
        };
        SloStatus {
            objective_us: self.config.objective_us,
            target: self.config.target,
            good,
            bad,
            compliance,
            burn_rate,
        }
    }

    /// Evaluate *and* latch: the returned flag is `true` only on the
    /// evaluation that first sees the budget exhausted (re-armed once a
    /// later evaluation sees it recovered), so callers can journal one
    /// event per excursion.
    pub fn track(&self, latencies: &LogHistogram) -> (SloStatus, bool) {
        let status = self.evaluate(latencies);
        let newly = if status.exhausted() {
            !self.exhausted.swap(true, Ordering::Relaxed)
        } else {
            self.exhausted.store(false, Ordering::Relaxed);
            false
        };
        (status, newly)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// 90 fast samples at 10 µs, 10 slow at 1024 µs — both on exact
    /// bucket boundaries relative to a 16- or 100-µs objective.
    fn hist_90_10() -> LogHistogram {
        let mut h = LogHistogram::new();
        for _ in 0..90 {
            h.record(10_000);
        }
        for _ in 0..10 {
            h.record(1_024_000);
        }
        h
    }

    #[test]
    fn compliance_math_is_exact_on_a_hand_built_histogram() {
        let h = hist_90_10();
        // Objective 16 µs: 16_384 ns tops its bucket ladder? We need an
        // aligned edge — (1<<14)-1 ns = 16.383 µs. Use 16_383/1000 ≈ 16 µs:
        // count_le(16_000_000? no). Use a 16 µs objective: 16_000 ns sits
        // mid-bucket above LINEAR_MAX, but every recorded sample is far
        // from the boundary (10_000 and 1_024_000), so the partial
        // bucket is empty and the count is still exact.
        let t = SloTracker::new(SloConfig::new(16, 0.95));
        let s = t.evaluate(&h);
        assert_eq!(s.good, 90);
        assert_eq!(s.bad, 10);
        assert_eq!(s.total(), 100);
        assert!((s.compliance - 0.90).abs() < 1e-12);
        // Allowed bad fraction 5 %, observed 10 % → burn rate exactly 2.
        assert!((s.burn_rate - 2.0).abs() < 1e-12, "burn {}", s.burn_rate);
        assert!(s.exhausted());
    }

    #[test]
    fn inside_budget_burn_is_fractional() {
        let h = hist_90_10();
        // Allowed 20 % bad, observed 10 % → burn 0.5, compliant.
        let t = SloTracker::new(SloConfig::new(16, 0.80));
        let s = t.evaluate(&h);
        assert!((s.burn_rate - 0.5).abs() < 1e-12);
        assert!(!s.exhausted());
        // A generous objective admits everything.
        let t = SloTracker::new(SloConfig::new(2_000, 0.99));
        let s = t.evaluate(&h);
        assert_eq!(s.good, 100);
        assert_eq!(s.compliance, 1.0);
        assert_eq!(s.burn_rate, 0.0);
    }

    #[test]
    fn empty_window_is_compliant() {
        let t = SloTracker::new(SloConfig::new(100, 0.99));
        let s = t.evaluate(&LogHistogram::new());
        assert_eq!(s.total(), 0);
        assert_eq!(s.compliance, 1.0);
        assert_eq!(s.burn_rate, 0.0);
        assert!(!s.exhausted());
    }

    #[test]
    fn perfect_target_burns_infinitely_on_any_miss() {
        let mut h = LogHistogram::new();
        h.record(10_000);
        h.record(1_024_000);
        let t = SloTracker::new(SloConfig::new(16, 1.0));
        let s = t.evaluate(&h);
        assert!(s.burn_rate.is_infinite());
        assert!(s.exhausted());
        // ...but a perfect history stays at zero burn.
        let mut fast = LogHistogram::new();
        fast.record(10_000);
        assert_eq!(t.evaluate(&fast).burn_rate, 0.0);
    }

    #[test]
    fn track_latches_the_exhaustion_edge() {
        let t = SloTracker::new(SloConfig::new(16, 0.95));
        let mut h = LogHistogram::new();
        for _ in 0..99 {
            h.record(10_000);
        }
        let (s, newly) = t.track(&h);
        assert!(!s.exhausted());
        assert!(!newly);
        // Ten slow answers push past the 5 % budget: edge fires once.
        for _ in 0..10 {
            h.record(1_024_000);
        }
        let (s, newly) = t.track(&h);
        assert!(s.exhausted());
        assert!(newly, "first exhausted evaluation reports the edge");
        let (_, again) = t.track(&h);
        assert!(!again, "still exhausted is not a new edge");
        // Recovery re-arms the latch.
        for _ in 0..900 {
            h.record(10_000);
        }
        let (s, newly) = t.track(&h);
        assert!(!s.exhausted());
        assert!(!newly);
        for _ in 0..90 {
            h.record(1_024_000);
        }
        let (_, refires) = t.track(&h);
        assert!(refires, "a fresh excursion journals again");
    }

    #[test]
    fn off_boundary_objective_snaps_to_its_bucket_top() {
        // 50 µs = 50_000 ns is NOT a bucket boundary: its bucket is
        // [49_152, 50_176). The effective objective is the bucket top.
        let c = SloConfig::new(50, 0.99);
        assert_eq!(c.objective_ns(), 50_175);
        assert_eq!(c.objective_us, 50, "configured value is preserved for display");

        // Regression: a single sample inside the objective's own bucket
        // but numerically above the raw 50_000 ns. The unsnapped code
        // called count_le(50_000), whose min-clamp (50_000 < min=50_100)
        // returned 0 — an under-count that flipped compliance to 0 and
        // burn to 100× even though the histogram cannot distinguish
        // 50_100 from 50_000. Snapped, the count is exact per the
        // bucket-top contract.
        let mut h = LogHistogram::new();
        h.record(50_100);
        let s = SloTracker::new(c).evaluate(&h);
        assert_eq!((s.good, s.bad), (1, 0), "in-bucket sample counts good");
        assert_eq!(s.compliance, 1.0);

        // Exactness at the snapped edge: 50_175 is the last good value,
        // 50_176 the first bad one.
        let mut h = LogHistogram::new();
        h.record(50_175);
        h.record(50_176);
        let s = SloTracker::new(c).evaluate(&h);
        assert_eq!((s.good, s.bad), (1, 1));

        // Round sub-LINEAR_MAX-µs objectives were exact before and stay
        // bucket-aligned after the snap (16 µs = 16_000 ns tops nothing
        // below LINEAR_MAX ns, but its snap is still deterministic).
        let c16 = SloConfig::new(16, 0.95);
        assert_eq!(c16.objective_ns(), LogHistogram::bucket_top(16_000));
    }

    #[test]
    fn target_is_clamped() {
        let c = SloConfig::new(100, 7.0);
        assert_eq!(c.target, 1.0);
        let c = SloConfig::new(100, -3.0);
        assert!(c.target > 0.0);
    }
}
