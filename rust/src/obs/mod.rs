//! End-to-end tracing & profiling: per-request spans, per-round
//! cycle/energy attribution, Chrome-trace + Prometheus-style export.
//!
//! The paper's whole value claim is an accounting argument — TCD-MAC
//! wins because carry-deferring moves cycles and energy out of the
//! steady-state rolls and into one deferred completion round — and this
//! module makes that accounting visible *per execution* instead of only
//! as end-of-run aggregates:
//!
//! * [`span`] — the [`Tracer`]/[`TrackHandle`] pair threaded from
//!   [`ServeBuilder`](crate::serve::ServeBuilder) through the
//!   coordinator and fleet into every engine: typed wall spans (submit →
//!   admission → queue wait → batch assembly → execute → respond) plus a
//!   deterministic simulated-time [`BatchTrace`] per executed batch.
//! * [`profile`] — [`BatchProfile`]/[`LayerProfile`]/[`RoundProfile`],
//!   the per-layer, per-round attribution the execution core fills
//!   during its roll walk (rolls, config-switch cycles, the TCD
//!   deferred-completion tail, active MAC-cycles, SRAM row traffic).
//! * [`chrome`] — [`chrome_trace_json`]: a Perfetto-loadable
//!   Chrome-trace export, one wall track per pipeline lane and one
//!   simulated-time track per device, with exact integer cycle args so
//!   per-batch span sums equal the engine's reported
//!   `DataflowReport.cycles`.
//! * [`export`] — [`MetricsSnapshot`]: coordinator counters + per-layer
//!   aggregation as Prometheus text exposition or a JSON snapshot,
//!   reachable from
//!   [`NpeService::metrics_snapshot`](crate::serve::NpeService::metrics_snapshot).
//! * [`hist`] — [`LogHistogram`], the constant-memory log-bucketed
//!   histogram behind the coordinator's latency percentiles.
//! * [`timeline`] — [`TelemetrySampler`]: a background (or, for tests,
//!   manually ticked and therefore deterministic) gauge sampler feeding
//!   a bounded ring of queue-depth / in-flight / per-device-occupancy
//!   samples — the live feedback signal elastic pools will consume —
//!   exported as Prometheus gauges, `timeline_json()`, and a
//!   Chrome-trace counter track ([`chrome_trace_json_with`]).
//! * [`slo`] — per-tenant [`SloTracker`]: latency objective + target
//!   fraction evaluated against the existing latency histograms into
//!   good/bad counts, compliance, and error-budget burn rate.
//! * [`journal`] — [`EventJournal`]: a bounded, per-tenant-queryable
//!   structured event log (device lost, shed, admission reject, cache
//!   eviction, SLO budget exhausted) with monotonic sequence numbers
//!   and drop counting on overflow.
//!
//! Everything here is dependency-free and hand-rolled, like the rest of
//! the repo: no serde, no tracing crates — the JSON writers live next
//! to a matching minimal parser ([`crate::util::json`]) used by the
//! schema tests.

pub mod chrome;
pub mod export;
pub mod hist;
pub mod journal;
pub mod profile;
pub mod slo;
pub mod span;
pub mod timeline;

pub use chrome::{chrome_trace_json, chrome_trace_json_with};
pub use export::{aggregate_layers, merge_expositions, LayerAgg, MetricsSnapshot};
pub use hist::LogHistogram;
pub use journal::{EventJournal, EventKind, JournalEvent, JournalSink, Severity};
pub use profile::{BatchProfile, LayerProfile, RoundProfile};
pub use slo::{SloConfig, SloStatus, SloTracker};
pub use span::{BatchTrace, SpanKind, TraceLog, Tracer, TrackHandle, WallSpan};
pub use timeline::{
    BusyLanes, SamplerConfig, SamplerMode, TelemetrySample, TelemetrySampler, TelemetrySource,
    TimelineSnapshot,
};
