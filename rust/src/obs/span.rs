//! [`Tracer`] — the span recorder threaded through the serving path.
//!
//! One `Arc<Tracer>` is created by
//! [`ServeBuilder::tracing`](crate::serve::ServeBuilder::tracing) (or
//! attached with [`ServeBuilder::tracer`](crate::serve::ServeBuilder::tracer)
//! to share a tracer across services) and handed down: the submit gate
//! records `submit`/`admission` spans, the coordinator loop records
//! `queue-wait`/`batch-assembly`/`respond`, and every executing engine
//! holds a [`TrackHandle`] — one registered track per simulated device —
//! through which it records an `execute` wall span plus the full
//! simulated-time [`BatchProfile`] of each batch it runs.
//!
//! Two clocks, kept separate by construction:
//! * **wall time** — host `Instant`s relative to the tracer epoch,
//!   stored in [`WallSpan`]s (and the wall envelope of [`BatchTrace`]);
//! * **simulated NPE time** — cycles and ns from the engine's own
//!   accounting, stored in [`BatchTrace`]/[`BatchProfile`] and fully
//!   deterministic for a seeded run (the determinism test relies on
//!   this split: strip the wall track and two identical runs emit
//!   identical traces).
//!
//! Buffers are bounded ([`WALL_SPAN_CAP`], [`BATCH_CAP`]); overflow
//! increments [`TraceLog::dropped_events`] rather than silently
//! truncating.

use super::profile::BatchProfile;
use crate::dataflow::DataflowReport;
use crate::util;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Wall-span buffer bound (spans beyond this are counted as dropped).
pub const WALL_SPAN_CAP: usize = 1 << 20;
/// Batch-trace buffer bound.
pub const BATCH_CAP: usize = 1 << 16;

/// The typed wall-side span taxonomy of one request's lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// Client-side submit call (shape/admission checks included).
    Submit,
    /// Admission-control decision inside the submit gate.
    Admission,
    /// Admitted request waiting to be drained into a batch.
    QueueWait,
    /// Batcher assembly: first arrival of the batch → dispatch.
    BatchAssembly,
    /// Engine execution of one batch (wall envelope of the sim work).
    Execute,
    /// Response fan-out back to the tickets.
    Respond,
}

impl SpanKind {
    pub fn name(&self) -> &'static str {
        match self {
            SpanKind::Submit => "submit",
            SpanKind::Admission => "admission",
            SpanKind::QueueWait => "queue-wait",
            SpanKind::BatchAssembly => "batch-assembly",
            SpanKind::Execute => "execute",
            SpanKind::Respond => "respond",
        }
    }
}

/// One wall-clock span, epoch-relative.
#[derive(Debug, Clone)]
pub struct WallSpan {
    pub kind: SpanKind,
    /// Track (device/pipeline lane) index from [`Tracer::register_track`].
    pub track: u32,
    /// Batch id, when the span belongs to a dispatched batch.
    pub batch: Option<u64>,
    /// Request trace id, when the span belongs to one request.
    pub request: Option<u64>,
    pub start_ns: u64,
    pub dur_ns: u64,
}

/// One executed batch: wall envelope + the deterministic simulated-time
/// attribution the Chrome exporter turns into nested device-track spans.
#[derive(Debug, Clone)]
pub struct BatchTrace {
    pub track: u32,
    pub batch: u64,
    /// Real (unpadded) requests in the batch.
    pub requests: usize,
    pub wall_start_ns: u64,
    pub wall_dur_ns: u64,
    /// The engine's reported total (`DataflowReport.cycles`).
    pub cycles: u64,
    /// Simulated NPE time (`DataflowReport.time_ns`).
    pub time_ns: f64,
    /// Total simulated energy, pJ.
    pub energy_pj: f64,
    /// PE dynamic energy, pJ (distributed over layers by the exporter,
    /// proportional to each layer's active MAC-cycles).
    pub pe_dynamic_pj: f64,
    /// Active MAC-cycles of the whole batch.
    pub active_mac_cycles: u64,
    pub profile: BatchProfile,
}

#[derive(Debug, Default)]
struct TraceBuf {
    wall: Vec<WallSpan>,
    batches: Vec<BatchTrace>,
    dropped: u64,
}

/// Immutable snapshot of everything recorded so far.
#[derive(Debug, Clone, Default)]
pub struct TraceLog {
    /// Track names, indexed by [`WallSpan::track`]/[`BatchTrace::track`].
    pub tracks: Vec<String>,
    pub wall: Vec<WallSpan>,
    pub batches: Vec<BatchTrace>,
    /// Events lost to the buffer bounds (0 in healthy runs).
    pub dropped_events: u64,
}

/// The span recorder. Cheap enough to sit on the serving hot path: a
/// span record is one short mutex hold and a `Vec` push.
pub struct Tracer {
    epoch: Instant,
    inner: Mutex<TraceBuf>,
    tracks: Mutex<Vec<String>>,
    next_batch: AtomicU64,
    next_request: AtomicU64,
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Self {
            epoch: Instant::now(),
            inner: Mutex::new(TraceBuf::default()),
            tracks: Mutex::new(Vec::new()),
            next_batch: AtomicU64::new(0),
            next_request: AtomicU64::new(0),
        }
    }

    /// The usual construction: one tracer shared across a service (or
    /// several — tracks keep multi-service traces apart).
    pub fn shared() -> Arc<Self> {
        Arc::new(Self::new())
    }

    /// The tracer's epoch instant — shared with the telemetry sampler
    /// so counter-track timestamps line up with span timestamps in one
    /// Perfetto timebase.
    pub fn epoch(&self) -> Instant {
        self.epoch
    }

    /// Nanoseconds since the tracer epoch.
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// Epoch-relative ns of an `Instant` taken elsewhere (0 if it
    /// predates the epoch).
    pub fn ns_of(&self, t: Instant) -> u64 {
        t.saturating_duration_since(self.epoch).as_nanos() as u64
    }

    /// Register a named track (a device lane, or the request pipeline of
    /// one service) and get its index. Names need not be unique; the
    /// exporter disambiguates by index.
    pub fn register_track(self: &Arc<Self>, name: &str) -> TrackHandle {
        let mut tracks = util::lock(&self.tracks);
        let idx = tracks.len() as u32;
        tracks.push(name.to_string());
        TrackHandle { tracer: Arc::clone(self), track: idx }
    }

    /// Next request trace id (monotonic per tracer).
    pub fn next_request_id(&self) -> u64 {
        self.next_request.fetch_add(1, Ordering::Relaxed)
    }

    fn next_batch_id(&self) -> u64 {
        self.next_batch.fetch_add(1, Ordering::Relaxed)
    }

    fn push_wall(&self, span: WallSpan) {
        let mut buf = util::lock(&self.inner);
        if buf.wall.len() < WALL_SPAN_CAP {
            buf.wall.push(span);
        } else {
            buf.dropped += 1;
        }
    }

    /// Snapshot everything recorded so far (spans sorted by start time,
    /// batches by track then batch id — a stable, render-ready order).
    pub fn snapshot(&self) -> TraceLog {
        let tracks = util::lock(&self.tracks).clone();
        let buf = util::lock(&self.inner);
        let mut wall = buf.wall.clone();
        wall.sort_by_key(|s| (s.start_ns, s.track));
        let mut batches = buf.batches.clone();
        batches.sort_by_key(|b| (b.track, b.batch));
        TraceLog { tracks, wall, batches, dropped_events: buf.dropped }
    }
}

/// A cloneable handle bound to one track: what engines and the
/// coordinator actually record through.
#[derive(Clone)]
pub struct TrackHandle {
    tracer: Arc<Tracer>,
    track: u32,
}

impl TrackHandle {
    pub fn tracer(&self) -> &Arc<Tracer> {
        &self.tracer
    }

    pub fn track(&self) -> u32 {
        self.track
    }

    /// Record a wall span that started at `start` and ends now.
    pub fn span_since(&self, kind: SpanKind, start: Instant, request: Option<u64>) {
        let start_ns = self.tracer.ns_of(start);
        let end_ns = self.tracer.now_ns();
        self.tracer.push_wall(WallSpan {
            kind,
            track: self.track,
            batch: None,
            request,
            start_ns,
            dur_ns: end_ns.saturating_sub(start_ns),
        });
    }

    /// Record one executed batch: the `execute` wall span plus the full
    /// simulated-time attribution. Returns the batch id.
    pub fn record_batch(
        &self,
        started: Instant,
        requests: usize,
        profile: BatchProfile,
        report: &DataflowReport,
        active_mac_cycles: u64,
    ) -> u64 {
        let batch = self.tracer.next_batch_id();
        let start_ns = self.tracer.ns_of(started);
        let dur_ns = self.tracer.now_ns().saturating_sub(start_ns);
        let mut buf = util::lock(&self.tracer.inner);
        if buf.wall.len() < WALL_SPAN_CAP {
            buf.wall.push(WallSpan {
                kind: SpanKind::Execute,
                track: self.track,
                batch: Some(batch),
                request: None,
                start_ns,
                dur_ns,
            });
        } else {
            buf.dropped += 1;
        }
        if buf.batches.len() < BATCH_CAP {
            buf.batches.push(BatchTrace {
                track: self.track,
                batch,
                requests,
                wall_start_ns: start_ns,
                wall_dur_ns: dur_ns,
                cycles: report.cycles,
                time_ns: report.time_ns,
                energy_pj: report.energy.total_pj(),
                pe_dynamic_pj: report.energy.pe_dynamic_pj,
                active_mac_cycles,
                profile,
            });
        } else {
            buf.dropped += 1;
        }
        batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::EnergyBreakdown;

    fn report(cycles: u64) -> DataflowReport {
        DataflowReport {
            dataflow: "test",
            mac: "tcd",
            outputs: Vec::new(),
            cycles,
            time_ns: cycles as f64 * 2.0,
            energy: EnergyBreakdown {
                pe_dynamic_pj: 10.0,
                pe_leak_pj: 1.0,
                mem_dynamic_pj: 2.0,
                mem_leak_pj: 0.5,
                dram_pj: 3.0,
            },
        }
    }

    #[test]
    fn tracks_spans_and_batches_round_trip() {
        let tracer = Tracer::shared();
        let pipeline = tracer.register_track("pipeline");
        let dev = tracer.register_track("device 0 [16x8]");
        assert_eq!(pipeline.track(), 0);
        assert_eq!(dev.track(), 1);

        let t0 = Instant::now();
        pipeline.span_since(SpanKind::Submit, t0, Some(7));
        let id = dev.record_batch(t0, 3, BatchProfile::default(), &report(100), 42);
        assert_eq!(id, 0);
        let id2 = dev.record_batch(t0, 1, BatchProfile::default(), &report(50), 10);
        assert_eq!(id2, 1, "batch ids are monotonic");

        let log = tracer.snapshot();
        assert_eq!(log.tracks, vec!["pipeline", "device 0 [16x8]"]);
        assert_eq!(log.batches.len(), 2);
        assert_eq!(log.batches[0].cycles, 100);
        assert_eq!(log.batches[0].requests, 3);
        assert!((log.batches[0].energy_pj - 16.5).abs() < 1e-9);
        // Submit span + 2 execute spans.
        assert_eq!(log.wall.len(), 3);
        assert!(log.wall.iter().any(|s| s.kind == SpanKind::Submit && s.request == Some(7)));
        assert_eq!(log.dropped_events, 0);
    }

    #[test]
    fn request_ids_are_monotonic() {
        let tracer = Tracer::shared();
        assert_eq!(tracer.next_request_id(), 0);
        assert_eq!(tracer.next_request_id(), 1);
    }
}
