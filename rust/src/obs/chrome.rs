//! Chrome-trace-event JSON export (Perfetto-loadable).
//!
//! Two processes, one thread (track) per registered tracer track:
//!
//! * **pid 0 — "wall: request pipeline"**: every [`WallSpan`] as a
//!   complete (`ph:"X"`) event in host wall time. Timestamps here vary
//!   run to run; the determinism test strips this pid.
//! * **pid 1 — "sim: NPE devices"**: the deterministic simulated-time
//!   reconstruction of every executed batch, as nested `ph:"B"`/`"E"`
//!   spans. Each device track keeps a *cycle cursor*: batches abut
//!   back-to-back in simulated time, and inside a batch the span tree is
//!
//!   ```text
//!   batch N                     cycles = DataflowReport.cycles
//!   ├─ layer i Γ(B,I,U)         cycles = compute + switch
//!   │  ├─ config-switch (X)     cycles = 1        (per round)
//!   │  └─ round KxN             cycles = stream + deferred
//!   │     └─ deferred-completion (X)  the TCD tail, annotation
//!   ├─ ...
//!   └─ overhead (X)             cycles = batch − Σ layers
//!   ```
//!
//!   Every sim event carries integer `start_cycle`/`cycles` args, so
//!   the schema tests can assert **exact** containment and per-batch
//!   sums (children of a batch sum to the batch's cycles; children of a
//!   layer sum to the layer's) without trusting float timestamps.
//!   Timestamps (µs) are derived from the batch's own ns-per-cycle
//!   (`time_ns / cycles`), so sim span durations also sum to
//!   `DataflowReport.time_ns` within float rounding.

use super::profile::BatchProfile;
use super::span::{BatchTrace, TraceLog};
use super::timeline::TimelineSnapshot;
use crate::util::json::escape;
use std::fmt::Write as _;

/// pid of the wall-clock request-pipeline process.
pub const WALL_PID: u32 = 0;
/// pid of the simulated NPE-device process.
pub const SIM_PID: u32 = 1;

/// Render a snapshot as a Chrome trace (JSON object form with a
/// `traceEvents` array — load it at <https://ui.perfetto.dev>).
pub fn chrome_trace_json(log: &TraceLog) -> String {
    chrome_trace_json_with(log, None)
}

/// Like [`chrome_trace_json`], plus counter tracks (`ph:"C"`) from a
/// telemetry timeline: `npe load` (queue depth + in-flight) and
/// `npe occupancy` (one series per device), on the wall pid so Perfetto
/// draws queue pressure directly above the request-pipeline spans. The
/// sampler must share the tracer's epoch
/// ([`TelemetrySampler::with_epoch`](super::timeline::TelemetrySampler::with_epoch))
/// for the timestamps to line up.
pub fn chrome_trace_json_with(log: &TraceLog, timeline: Option<&TimelineSnapshot>) -> String {
    let mut events: Vec<String> = Vec::new();

    // Metadata: process and thread names for both pids.
    for (pid, pname) in [(WALL_PID, "wall: request pipeline"), (SIM_PID, "sim: NPE devices")] {
        events.push(format!(
            r#"{{"ph":"M","pid":{pid},"tid":0,"name":"process_name","args":{{"name":"{}"}}}}"#,
            escape(pname)
        ));
        for (tid, track) in log.tracks.iter().enumerate() {
            events.push(format!(
                r#"{{"ph":"M","pid":{pid},"tid":{tid},"name":"thread_name","args":{{"name":"{}"}}}}"#,
                escape(track)
            ));
        }
    }

    // Wall side: every span as a complete event.
    for s in &log.wall {
        let mut args = String::new();
        if let Some(b) = s.batch {
            let _ = write!(args, r#""batch":{b}"#);
        }
        if let Some(r) = s.request {
            if !args.is_empty() {
                args.push(',');
            }
            let _ = write!(args, r#""request":{r}"#);
        }
        events.push(format!(
            r#"{{"ph":"X","pid":{WALL_PID},"tid":{},"name":"{}","ts":{},"dur":{},"args":{{{args}}}}}"#,
            s.track,
            s.kind.name(),
            us(s.start_ns as f64),
            us(s.dur_ns as f64),
        ));
    }

    // Sim side: one cycle cursor per track, batches back-to-back.
    let batch_tracks = log.batches.iter().map(|b| b.track as usize + 1).max().unwrap_or(0);
    let n_tracks = log.tracks.len().max(batch_tracks);
    let mut cursor_cycles = vec![0u64; n_tracks];
    let mut cursor_ns = vec![0f64; n_tracks];
    for b in &log.batches {
        let t = b.track as usize;
        emit_batch(&mut events, b, cursor_cycles[t], cursor_ns[t]);
        cursor_cycles[t] += b.cycles;
        cursor_ns[t] += b.time_ns;
    }

    // Counter tracks: one "npe load" counter (queue depth + in-flight)
    // and one "npe occupancy" counter (a series per device), sampled by
    // the telemetry timeline.
    if let Some(tl) = timeline {
        for s in &tl.samples {
            let ts = us(s.wall_ns as f64);
            events.push(format!(
                r#"{{"ph":"C","pid":{WALL_PID},"tid":0,"name":"npe load","ts":{ts},"args":{{"queue_depth":{},"in_flight":{}}}}}"#,
                s.queue_depth, s.in_flight,
            ));
            if !s.occupancy.is_empty() {
                let series = s
                    .occupancy
                    .iter()
                    .enumerate()
                    .map(|(i, o)| format!(r#""device {i}":{o:.4}"#))
                    .collect::<Vec<_>>()
                    .join(",");
                events.push(format!(
                    r#"{{"ph":"C","pid":{WALL_PID},"tid":0,"name":"npe occupancy","ts":{ts},"args":{{{series}}}}}"#,
                ));
            }
        }
    }

    let mut out = String::from("{\"displayTimeUnit\":\"ns\",\"traceEvents\":[\n");
    out.push_str(&events.join(",\n"));
    out.push_str("\n]}\n");
    out
}

/// Format a µs timestamp with ns precision.
fn us(ns: f64) -> String {
    format!("{:.3}", ns / 1e3)
}

/// Emit one batch's nested sim spans starting at `base_cycle`/`base_ns`
/// on its track.
fn emit_batch(events: &mut Vec<String>, b: &BatchTrace, base_cycle: u64, base_ns: f64) {
    let tid = b.track;
    let ns_per_cycle = if b.cycles > 0 { b.time_ns / b.cycles as f64 } else { 0.0 };
    let ts_of = |cycle: u64| us(base_ns + (cycle - base_cycle) as f64 * ns_per_cycle);

    let begin = |events: &mut Vec<String>, name: &str, cycle: u64, args: String| {
        events.push(format!(
            r#"{{"ph":"B","pid":{SIM_PID},"tid":{tid},"name":"{}","ts":{},"args":{{"start_cycle":{cycle},{args}}}}}"#,
            escape(name),
            ts_of(cycle),
        ));
    };
    let end = |events: &mut Vec<String>, name: &str, cycle: u64| {
        events.push(format!(
            r#"{{"ph":"E","pid":{SIM_PID},"tid":{tid},"name":"{}","ts":{}}}"#,
            escape(name),
            ts_of(cycle),
        ));
    };
    let complete = |events: &mut Vec<String>, name: &str, cycle: u64, cycles: u64, args: String| {
        events.push(format!(
            r#"{{"ph":"X","pid":{SIM_PID},"tid":{tid},"name":"{}","ts":{},"dur":{},"args":{{"start_cycle":{cycle},"cycles":{cycles},{args}}}}}"#,
            escape(name),
            ts_of(cycle),
            us(cycles as f64 * ns_per_cycle),
        ));
    };

    let batch_name = format!("batch {}", b.batch);
    begin(
        events,
        &batch_name,
        base_cycle,
        format!(
            r#""cycles":{},"requests":{},"time_ns":{:.3},"energy_pj":{:.3},"pe_dynamic_pj":{:.3},"active_mac_cycles":{}"#,
            b.cycles, b.requests, b.time_ns, b.energy_pj, b.pe_dynamic_pj, b.active_mac_cycles
        ),
    );

    let total_amc = total_active_mac_cycles(&b.profile).max(1);
    let mut cycle = base_cycle;
    for layer in &b.profile.layers {
        let layer_name = format!(
            "layer {} Γ({},{},{})",
            layer.index, layer.batches, layer.inputs, layer.neurons
        );
        let schedule = match layer.cache_hit {
            Some(true) => "hit",
            Some(false) => "miss",
            None => "memo",
        };
        let layer_pj = b.pe_dynamic_pj * layer.active_mac_cycles as f64 / total_amc as f64;
        begin(
            events,
            &layer_name,
            cycle,
            format!(
                r#""cycles":{},"rolls":{},"deferred_cycles":{},"schedule":"{schedule}","mapper_wall_ns":{},"pe_dynamic_pj":{layer_pj:.3}"#,
                layer.total_cycles(),
                layer.rolls(),
                layer.deferred_cycles(),
                layer.mapper_wall_ns,
            ),
        );
        for round in &layer.rounds {
            if round.switch_cycles > 0 {
                complete(
                    events,
                    "config-switch",
                    cycle,
                    round.switch_cycles,
                    format!(r#""config":"{}x{}""#, round.config.0, round.config.1),
                );
                cycle += round.switch_cycles;
            }
            let round_name = format!("round {}x{}", round.config.0, round.config.1);
            begin(
                events,
                &round_name,
                cycle,
                format!(
                    r#""cycles":{},"rolls":{},"stream_cycles":{},"deferred_cycles":{},"active_mac_cycles":{}"#,
                    round.compute_cycles(),
                    round.rolls,
                    round.stream_cycles,
                    round.deferred_cycles,
                    round.active_mac_cycles,
                ),
            );
            if round.deferred_cycles > 0 {
                // The TCD tail: drawn at the end of the round.
                complete(
                    events,
                    "deferred-completion",
                    cycle + round.stream_cycles,
                    round.deferred_cycles,
                    format!(r#""rolls":{}"#, round.rolls),
                );
            }
            cycle += round.compute_cycles();
            end(events, &round_name, cycle);
        }
        end(events, &layer_name, cycle);
    }

    // Whatever the profile did not attribute (layer swaps, non-GEMM
    // graph stages) becomes one explicit overhead span, so the batch's
    // children always sum exactly to its reported cycles.
    let attributed = cycle - base_cycle;
    let remainder = b.cycles.saturating_sub(attributed);
    if remainder > 0 {
        complete(events, "overhead", cycle, remainder, r#""kind":"output + layer swaps""#.into());
    }
    end(events, &batch_name, base_cycle + b.cycles);
}

fn total_active_mac_cycles(p: &BatchProfile) -> u64 {
    p.layers.iter().map(|l| l.active_mac_cycles).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::profile::{LayerProfile, RoundProfile};
    use crate::util::json::JsonValue;

    fn sample_log() -> TraceLog {
        let layer = LayerProfile {
            index: 0,
            batches: 2,
            inputs: 8,
            neurons: 4,
            rounds: vec![RoundProfile {
                config: (4, 2),
                rolls: 2,
                stream_cycles: 16,
                deferred_cycles: 2,
                switch_cycles: 1,
                active_mac_cycles: 144,
            }],
            compute_cycles: 18,
            switch_cycles: 1,
            active_mac_cycles: 144,
            cache_hit: Some(true),
            ..Default::default()
        };
        TraceLog {
            tracks: vec!["device 0 [16x8]".into()],
            wall: Vec::new(),
            batches: vec![BatchTrace {
                track: 0,
                batch: 0,
                requests: 2,
                wall_start_ns: 0,
                wall_dur_ns: 10,
                cycles: 20, // 18 compute + 1 switch + 1 layer swap
                time_ns: 40.0,
                energy_pj: 5.0,
                pe_dynamic_pj: 3.0,
                active_mac_cycles: 144,
                profile: BatchProfile { layers: vec![layer] },
            }],
            dropped_events: 0,
        }
    }

    #[test]
    fn emits_valid_balanced_json() {
        let json = chrome_trace_json(&sample_log());
        let v = JsonValue::parse(&json).expect("valid JSON");
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        // B/E balance on the sim pid.
        let mut stack: Vec<String> = Vec::new();
        for e in events {
            let ph = e.get("ph").unwrap().as_str().unwrap();
            match ph {
                "B" => stack.push(e.get("name").unwrap().as_str().unwrap().to_string()),
                "E" => {
                    let open = stack.pop().expect("E without B");
                    assert_eq!(open, e.get("name").unwrap().as_str().unwrap());
                }
                _ => {}
            }
        }
        assert!(stack.is_empty(), "unclosed spans: {stack:?}");
        // The overhead span closes the cycle budget: 20 − (18+1) = 1.
        let overhead = events
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("overhead"))
            .expect("overhead span");
        assert_eq!(overhead.get("args").unwrap().get("cycles").unwrap().as_u64(), Some(1));
        // The deferred tail is visible.
        let tail = |e: &JsonValue| e.get("name").unwrap().as_str() == Some("deferred-completion");
        assert!(events.iter().any(tail), "the TCD tail span is emitted");
    }

    #[test]
    fn batches_abut_on_the_cycle_cursor() {
        let mut log = sample_log();
        let mut second = log.batches[0].clone();
        second.batch = 1;
        log.batches.push(second);
        let json = chrome_trace_json(&log);
        let v = JsonValue::parse(&json).unwrap();
        let starts: Vec<u64> = v
            .get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| {
                e.get("ph").unwrap().as_str() == Some("B")
                    && e.get("name").unwrap().as_str().unwrap().starts_with("batch ")
            })
            .map(|e| e.get("args").unwrap().get("start_cycle").unwrap().as_u64().unwrap())
            .collect();
        assert_eq!(starts, vec![0, 20], "second batch starts where the first ended");
    }

    #[test]
    fn timeline_becomes_counter_events() {
        use crate::obs::timeline::{TelemetrySample, TimelineSnapshot};
        let tl = TimelineSnapshot {
            device_names: vec!["device 0".into(), "device 1".into()],
            samples: vec![TelemetrySample {
                tick: 0,
                wall_ns: 2_000,
                queue_depth: 3,
                in_flight: 5,
                answered_total: 9,
                shed_total: 0,
                occupancy: vec![0.5, 0.0],
            }],
            dropped: 0,
            period_ns: 50_000_000,
        };
        let json = chrome_trace_json_with(&sample_log(), Some(&tl));
        let v = JsonValue::parse(&json).expect("valid JSON with counters");
        let events = v.get("traceEvents").unwrap().as_arr().unwrap();
        let counters: Vec<_> =
            events.iter().filter(|e| e.get("ph").unwrap().as_str() == Some("C")).collect();
        assert_eq!(counters.len(), 2, "one load + one occupancy counter per sample");
        let load = counters
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("npe load"))
            .expect("load counter");
        assert_eq!(load.get("args").unwrap().get("queue_depth").unwrap().as_u64(), Some(3));
        assert_eq!(load.get("args").unwrap().get("in_flight").unwrap().as_u64(), Some(5));
        let occ = counters
            .iter()
            .find(|e| e.get("name").unwrap().as_str() == Some("npe occupancy"))
            .expect("occupancy counter");
        assert_eq!(occ.get("args").unwrap().get("device 0").unwrap().as_f64(), Some(0.5));
        // Plain export is unchanged: no counter events.
        let plain = chrome_trace_json(&sample_log());
        assert!(!plain.contains(r#""ph":"C""#));
    }
}
