//! Live telemetry timeline: [`BusyLanes`] (per-device busy-ns stamps),
//! [`TelemetrySampler`] (a periodic gauge reader feeding a bounded ring
//! of [`TelemetrySample`]s), and [`TimelineSnapshot`] (the queryable /
//! exportable time series).
//!
//! The tracer (PR 6) answers "where did *this request's* time go"; the
//! metrics snapshot (PR 7) answers "what are the totals so far". The
//! timeline answers the question between them — *how did load evolve* —
//! which is exactly the rolling feedback signal the ROADMAP's elastic
//! device pools need: queue depth, in-flight count, per-device
//! occupancy, and answered/shed counters, sampled on a fixed cadence
//! into a fixed-capacity ring.
//!
//! Design constraints, in order:
//!
//! 1. **Lock-cheap.** Devices stamp busy time with one relaxed atomic
//!    add ([`BusyLanes::add`]); the sampler reads gauges through
//!    closures the serving layer wires up (queue depth, in-flight,
//!    answered, shed — all existing atomics or short lock holds). The
//!    hot path never blocks on the sampler.
//! 2. **Deterministic for tests.** In [`SamplerMode::Manual`] no thread
//!    runs; the test calls [`TelemetrySampler::tick`] at points of its
//!    own choosing (e.g. after a load wave fully quiesces), and
//!    [`TimelineSnapshot::fingerprint`] hashes only the
//!    wall-clock-independent fields (tick index, queue depth,
//!    in-flight, answered/shed totals) — so a seeded load replayed
//!    under manual ticks yields the *same fingerprint every run*.
//!    Occupancy and timestamps are wall-time-derived and deliberately
//!    excluded.
//! 3. **Bounded.** The ring holds `capacity` samples; overflow drops
//!    the oldest and counts the drop, like the event journal.
//!
//! Occupancy is Δbusy/Δwall per tick, clamped to `[0, 1]`: a device
//! that spent the whole inter-tick window executing reads 1.0, an idle
//! one 0.0.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, PoisonError};
use std::thread;
use std::time::{Duration, Instant};

use crate::obs::journal::{EventKind, JournalSink, Severity};
use crate::util::{json, lock};

/// One relaxed atomic per device lane accumulating wall busy-ns (the
/// device thread stamps each batch's execute duration). Shared between
/// the fleet (writers) and the sampler (reader).
#[derive(Debug)]
pub struct BusyLanes {
    lanes: Vec<AtomicU64>,
}

impl BusyLanes {
    pub fn new(devices: usize) -> Arc<Self> {
        Arc::new(Self { lanes: (0..devices).map(|_| AtomicU64::new(0)).collect() })
    }

    /// Stamp `ns` of busy time onto `lane`. Out-of-range lanes are
    /// ignored (a defensive no-op, not a panic — this sits on the
    /// device hot path).
    pub fn add(&self, lane: usize, ns: u64) {
        if let Some(l) = self.lanes.get(lane) {
            l.fetch_add(ns, Ordering::Relaxed);
        }
    }

    /// Accumulated busy-ns of one lane (0 for out-of-range lanes).
    pub fn total(&self, lane: usize) -> u64 {
        self.lanes.get(lane).map_or(0, |l| l.load(Ordering::Relaxed))
    }

    /// Accumulated busy-ns of every lane.
    pub fn totals(&self) -> Vec<u64> {
        self.lanes.iter().map(|l| l.load(Ordering::Relaxed)).collect()
    }

    pub fn len(&self) -> usize {
        self.lanes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lanes.is_empty()
    }
}

/// Where the tick cadence comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SamplerMode {
    /// A background thread ticks every `period`.
    Background,
    /// No thread; the owner calls [`TelemetrySampler::tick`] — the
    /// deterministic mode tests use.
    Manual,
}

/// Sampler knobs.
#[derive(Debug, Clone, Copy)]
pub struct SamplerConfig {
    /// Tick period in background mode (ignored in manual mode).
    pub period: Duration,
    /// Ring capacity in samples; overflow drops the oldest.
    pub capacity: usize,
    pub mode: SamplerMode,
}

impl Default for SamplerConfig {
    fn default() -> Self {
        Self { period: Duration::from_millis(50), capacity: 2048, mode: SamplerMode::Background }
    }
}

impl SamplerConfig {
    /// Deterministic test mode: no thread, caller-driven ticks.
    pub fn manual() -> Self {
        Self { mode: SamplerMode::Manual, ..Self::default() }
    }

    pub fn with_period(mut self, period: Duration) -> Self {
        self.period = period;
        self
    }

    pub fn with_capacity(mut self, capacity: usize) -> Self {
        self.capacity = capacity.max(1);
        self
    }
}

/// The gauges a sampler reads each tick, wired up by the serving layer
/// as closures over its existing counters. All must be cheap and
/// non-blocking (atomics or short lock holds).
pub struct TelemetrySource {
    /// Jobs waiting in the work queue (fleet queue depth, or the
    /// batcher's pending count on the single path).
    pub queue_depth: Box<dyn Fn() -> u64 + Send + Sync>,
    /// Admitted requests not yet answered.
    pub in_flight: Box<dyn Fn() -> u64 + Send + Sync>,
    /// Requests answered so far (monotonic).
    pub answered_total: Box<dyn Fn() -> u64 + Send + Sync>,
    /// Requests shed/refused by admission so far (monotonic).
    pub shed_total: Box<dyn Fn() -> u64 + Send + Sync>,
    /// Live device-pool size. Elastic pools resize at runtime, so this
    /// is a gauge like the others; fixed pools wire a constant.
    pub pool_devices: Box<dyn Fn() -> u64 + Send + Sync>,
    /// Per-device busy-ns lanes.
    pub busy: Arc<BusyLanes>,
    /// Display names per device lane, e.g. `device 0 [16x8]`.
    pub device_names: Vec<String>,
    /// Optional per-tick side probe (the service hangs journal checks —
    /// cache-eviction deltas, SLO budget transitions — here so the
    /// sampler stays generic).
    pub probe: Option<Box<dyn Fn() + Send + Sync>>,
    /// Fleet-wide journal sink for sampler-detected anomalies (today:
    /// cumulative-counter regressions). `None` disables the reporting,
    /// never the sampling.
    pub journal: Option<JournalSink>,
}

impl std::fmt::Debug for TelemetrySource {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TelemetrySource")
            .field("devices", &self.device_names)
            .finish_non_exhaustive()
    }
}

/// One ring entry: every gauge at one tick.
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySample {
    /// Tick index, monotonic from 0 across the sampler's lifetime
    /// (keeps counting past ring overflow).
    pub tick: u64,
    /// Epoch-relative wall time of the tick, ns (tracer timebase when
    /// the sampler was built against a tracer).
    pub wall_ns: u64,
    pub queue_depth: u64,
    pub in_flight: u64,
    pub answered_total: u64,
    pub shed_total: u64,
    /// Device-pool size at the tick (live lanes, not the max bound).
    pub pool_devices: u64,
    /// Per-device Δbusy/Δwall since the previous tick, clamped [0, 1].
    pub occupancy: Vec<f64>,
}

/// An owned copy of the ring — query, fingerprint, or export it freely
/// without holding sampler locks.
#[derive(Debug, Clone, PartialEq)]
pub struct TimelineSnapshot {
    pub device_names: Vec<String>,
    /// Retained samples, oldest first.
    pub samples: Vec<TelemetrySample>,
    /// Samples dropped to ring overflow.
    pub dropped: u64,
    /// Configured tick period, ns (0 in manual mode — ticks are
    /// caller-paced).
    pub period_ns: u64,
}

impl TimelineSnapshot {
    /// Newest sample, if any tick has happened.
    pub fn latest(&self) -> Option<&TelemetrySample> {
        self.samples.last()
    }

    /// FNV-1a hash over the wall-clock-independent fields of every
    /// retained sample — the determinism contract: identical seeded
    /// loads sampled at identical manual tick points hash identically
    /// across runs. Timestamps and occupancy (both wall-derived) are
    /// excluded.
    pub fn fingerprint(&self) -> u64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut mix = |v: u64| {
            for b in v.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x0000_0100_0000_01b3);
            }
        };
        mix(self.samples.len() as u64);
        mix(self.dropped);
        for s in &self.samples {
            mix(s.tick);
            mix(s.queue_depth);
            mix(s.in_flight);
            mix(s.answered_total);
            mix(s.shed_total);
            mix(s.pool_devices);
        }
        h
    }

    /// Answered-requests rate over the trailing `window` samples,
    /// requests/s (0 with fewer than two samples or no wall progress).
    pub fn throughput_rps(&self, window: usize) -> f64 {
        self.trailing_rate(window, |s| s.answered_total)
    }

    /// Shed rate over the trailing `window` samples, requests/s.
    pub fn shed_rate_rps(&self, window: usize) -> f64 {
        self.trailing_rate(window, |s| s.shed_total)
    }

    fn trailing_rate(&self, window: usize, field: impl Fn(&TelemetrySample) -> u64) -> f64 {
        let n = self.samples.len();
        if n < 2 || window < 2 {
            return 0.0;
        }
        let first = &self.samples[n - window.min(n)];
        let last = &self.samples[n - 1];
        let dt_ns = last.wall_ns.saturating_sub(first.wall_ns);
        if dt_ns == 0 {
            return 0.0;
        }
        let (a, b) = (field(first), field(last));
        if b < a {
            // A cumulative counter moved backwards (metrics-sink swap or
            // reset). The sampler journals the violation once at tick
            // time; the rate reads an explicit 0 rather than a silently
            // saturated difference.
            return 0.0;
        }
        (b - a) as f64 / (dt_ns as f64 * 1e-9)
    }

    /// The timeline as a self-describing JSON document (hand-rolled,
    /// like every exporter in this repo — no serde in the offline crate
    /// set).
    pub fn to_json(&self) -> String {
        let mut out = String::with_capacity(256 + self.samples.len() * 128);
        out.push_str("{\n  \"period_ns\": ");
        out.push_str(&self.period_ns.to_string());
        out.push_str(",\n  \"dropped\": ");
        out.push_str(&self.dropped.to_string());
        out.push_str(",\n  \"fingerprint\": ");
        out.push_str(&self.fingerprint().to_string());
        out.push_str(",\n  \"devices\": [");
        for (i, name) in self.device_names.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push('"');
            out.push_str(&json::escape(name));
            out.push('"');
        }
        out.push_str("],\n  \"samples\": [\n");
        for (i, s) in self.samples.iter().enumerate() {
            if i > 0 {
                out.push_str(",\n");
            }
            out.push_str(&format!(
                "    {{\"tick\": {}, \"wall_ns\": {}, \"queue_depth\": {}, \"in_flight\": {}, \
                 \"answered_total\": {}, \"shed_total\": {}, \"pool_devices\": {}, \
                 \"occupancy\": [{}]}}",
                s.tick,
                s.wall_ns,
                s.queue_depth,
                s.in_flight,
                s.answered_total,
                s.shed_total,
                s.pool_devices,
                s.occupancy
                    .iter()
                    .map(|o| format!("{o:.4}"))
                    .collect::<Vec<_>>()
                    .join(", "),
            ));
        }
        out.push_str("\n  ]\n}\n");
        out
    }

    /// Latest-sample gauges in Prometheus exposition format:
    /// `npe_queue_depth`, `npe_in_flight`,
    /// `npe_device_occupancy{device="..."}`, plus the rolling rates and
    /// the ring drop counter. Empty string before the first tick (no
    /// gauges is more honest than fabricated zeros).
    pub fn prometheus_gauges(&self) -> String {
        let Some(s) = self.latest() else {
            return String::new();
        };
        let mut out = String::new();
        out.push_str("# HELP npe_queue_depth Work-queue depth at the last telemetry tick.\n");
        out.push_str("# TYPE npe_queue_depth gauge\n");
        out.push_str(&format!("npe_queue_depth {}\n", s.queue_depth));
        out.push_str("# HELP npe_in_flight Admitted, unanswered requests at the last tick.\n");
        out.push_str("# TYPE npe_in_flight gauge\n");
        out.push_str(&format!("npe_in_flight {}\n", s.in_flight));
        out.push_str(
            "# HELP npe_device_occupancy Per-device busy fraction over the last tick window.\n",
        );
        out.push_str("# TYPE npe_device_occupancy gauge\n");
        for (i, o) in s.occupancy.iter().enumerate() {
            out.push_str(&format!("npe_device_occupancy{{device=\"{i}\"}} {o:.4}\n"));
        }
        out.push_str("# HELP npe_pool_devices Live device-pool size at the last tick.\n");
        out.push_str("# TYPE npe_pool_devices gauge\n");
        out.push_str(&format!("npe_pool_devices {}\n", s.pool_devices));
        out.push_str("# HELP npe_throughput_rps Answered-request rate over the trailing window.\n");
        out.push_str("# TYPE npe_throughput_rps gauge\n");
        out.push_str(&format!("npe_throughput_rps {:.3}\n", self.throughput_rps(16)));
        out.push_str("# HELP npe_shed_rps Shed-request rate over the trailing window.\n");
        out.push_str("# TYPE npe_shed_rps gauge\n");
        out.push_str(&format!("npe_shed_rps {:.3}\n", self.shed_rate_rps(16)));
        out.push_str("# HELP npe_timeline_dropped_samples Ring-overflow sample drops.\n");
        out.push_str("# TYPE npe_timeline_dropped_samples counter\n");
        out.push_str(&format!("npe_timeline_dropped_samples {}\n", self.dropped));
        out
    }
}

struct Ring {
    samples: VecDeque<TelemetrySample>,
    dropped: u64,
    next_tick: u64,
    /// Busy totals at the previous tick (occupancy deltas).
    last_busy: Vec<u64>,
    /// Wall-ns of the previous tick.
    last_wall_ns: u64,
}

struct SamplerInner {
    source: TelemetrySource,
    ring: Mutex<Ring>,
    capacity: usize,
    period: Duration,
    mode: SamplerMode,
    epoch: Instant,
    /// Background-thread shutdown: flag + condvar so `stop()` wakes the
    /// sleeper immediately instead of waiting out a period.
    stopping: AtomicBool,
    stop_gate: Mutex<bool>,
    stop_cv: Condvar,
    /// Warn-once latch for cumulative-counter regressions: the first
    /// violating tick journals, later ones stay quiet (a regressed sink
    /// would otherwise spam a Warn per tick until the window clears).
    regression_warned: AtomicBool,
}

impl SamplerInner {
    fn tick(&self) {
        let now_ns = self.epoch.elapsed().as_nanos() as u64;
        let queue_depth = (self.source.queue_depth)();
        let in_flight = (self.source.in_flight)();
        let answered_total = (self.source.answered_total)();
        let shed_total = (self.source.shed_total)();
        let pool_devices = (self.source.pool_devices)();
        let busy = self.source.busy.totals();
        let mut ring = lock(&self.ring);
        let regression = ring.samples.back().and_then(|prev| {
            if answered_total < prev.answered_total {
                Some(format!(
                    "answered_total regressed {} -> {} at tick {}",
                    prev.answered_total, answered_total, ring.next_tick
                ))
            } else if shed_total < prev.shed_total {
                Some(format!(
                    "shed_total regressed {} -> {} at tick {}",
                    prev.shed_total, shed_total, ring.next_tick
                ))
            } else {
                None
            }
        });
        let dt = now_ns.saturating_sub(ring.last_wall_ns);
        let occupancy: Vec<f64> = busy
            .iter()
            .zip(ring.last_busy.iter())
            .map(|(&now, &then)| {
                if dt == 0 {
                    0.0
                } else {
                    (now.saturating_sub(then) as f64 / dt as f64).clamp(0.0, 1.0)
                }
            })
            .collect();
        ring.last_busy = busy;
        ring.last_wall_ns = now_ns;
        let tick = ring.next_tick;
        ring.next_tick += 1;
        if ring.samples.len() == self.capacity {
            ring.samples.pop_front();
            ring.dropped += 1;
        }
        ring.samples.push_back(TelemetrySample {
            tick,
            wall_ns: now_ns,
            queue_depth,
            in_flight,
            answered_total,
            shed_total,
            pool_devices,
            occupancy,
        });
        drop(ring);
        if let Some(detail) = regression {
            if !self.regression_warned.swap(true, Ordering::Relaxed) {
                if let Some(journal) = &self.source.journal {
                    journal.event(EventKind::CounterRegression, Severity::Warn, detail);
                }
            }
        }
        if let Some(probe) = &self.source.probe {
            probe();
        }
    }

    fn snapshot(&self) -> TimelineSnapshot {
        let ring = lock(&self.ring);
        TimelineSnapshot {
            device_names: self.source.device_names.clone(),
            samples: ring.samples.iter().cloned().collect(),
            dropped: ring.dropped,
            period_ns: if self.mode == SamplerMode::Background {
                self.period.as_nanos() as u64
            } else {
                0
            },
        }
    }
}

/// The sampler handle the serving layer owns. Dropping (or calling
/// [`stop`](Self::stop)) joins the background thread, if any.
pub struct TelemetrySampler {
    inner: Arc<SamplerInner>,
    thread: Mutex<Option<thread::JoinHandle<()>>>,
}

impl std::fmt::Debug for TelemetrySampler {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("TelemetrySampler")
            .field("mode", &self.inner.mode)
            .field("period", &self.inner.period)
            .finish_non_exhaustive()
    }
}

impl TelemetrySampler {
    /// Build a sampler over `source`. In background mode the sampling
    /// thread starts immediately; epoch is "now" (see
    /// [`with_epoch`](Self::with_epoch) for tracer alignment).
    pub fn new(source: TelemetrySource, config: SamplerConfig) -> Arc<Self> {
        Self::with_epoch(source, config, Instant::now())
    }

    /// Like [`new`](Self::new) but timestamps ticks relative to
    /// `epoch` — pass the tracer's epoch so Chrome-trace counter events
    /// share the span timebase.
    pub fn with_epoch(source: TelemetrySource, config: SamplerConfig, epoch: Instant) -> Arc<Self> {
        let devices = source.busy.len();
        let inner = Arc::new(SamplerInner {
            source,
            ring: Mutex::new(Ring {
                samples: VecDeque::with_capacity(config.capacity.max(1)),
                dropped: 0,
                next_tick: 0,
                last_busy: vec![0; devices],
                last_wall_ns: epoch.elapsed().as_nanos() as u64,
            }),
            capacity: config.capacity.max(1),
            period: config.period,
            mode: config.mode,
            epoch,
            stopping: AtomicBool::new(false),
            stop_gate: Mutex::new(false),
            stop_cv: Condvar::new(),
            regression_warned: AtomicBool::new(false),
        });
        let thread = if config.mode == SamplerMode::Background {
            let worker = Arc::clone(&inner);
            thread::Builder::new()
                .name("telemetry-sampler".into())
                .spawn(move || {
                    loop {
                        let gate = lock(&worker.stop_gate);
                        let (gate, _) = worker
                            .stop_cv
                            .wait_timeout(gate, worker.period)
                            .unwrap_or_else(PoisonError::into_inner);
                        if *gate || worker.stopping.load(Ordering::Relaxed) {
                            return;
                        }
                        drop(gate);
                        worker.tick();
                    }
                })
                .ok()
        } else {
            None
        };
        Arc::new(Self { inner, thread: Mutex::new(thread) })
    }

    /// Take one sample now. The manual-mode driver; harmless (one extra
    /// sample) in background mode.
    pub fn tick(&self) {
        self.inner.tick();
    }

    /// Owned copy of the current ring.
    pub fn snapshot(&self) -> TimelineSnapshot {
        self.inner.snapshot()
    }

    /// The timeline as JSON (see [`TimelineSnapshot::to_json`]).
    pub fn timeline_json(&self) -> String {
        self.snapshot().to_json()
    }

    /// Stop the background thread (no-op in manual mode / second call).
    pub fn stop(&self) {
        self.inner.stopping.store(true, Ordering::Relaxed);
        *lock(&self.inner.stop_gate) = true;
        self.inner.stop_cv.notify_all();
        if let Some(h) = lock(&self.thread).take() {
            let _ = h.join();
        }
    }

    /// Ticks taken so far (monotonic, past ring overflow).
    pub fn ticks(&self) -> u64 {
        lock(&self.inner.ring).next_tick
    }
}

impl Drop for TelemetrySampler {
    fn drop(&mut self) {
        self.stop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    fn counter_source(
        depth: &Arc<AtomicU64>,
        answered: &Arc<AtomicU64>,
        busy: &Arc<BusyLanes>,
    ) -> TelemetrySource {
        let d = Arc::clone(depth);
        let a = Arc::clone(answered);
        let devices = busy.len() as u64;
        TelemetrySource {
            queue_depth: Box::new(move || d.load(Ordering::Relaxed)),
            in_flight: Box::new(|| 0),
            answered_total: Box::new(move || a.load(Ordering::Relaxed)),
            shed_total: Box::new(|| 0),
            pool_devices: Box::new(move || devices),
            busy: Arc::clone(busy),
            device_names: (0..busy.len()).map(|i| format!("device {i}")).collect(),
            probe: None,
            journal: None,
        }
    }

    #[test]
    fn manual_ticks_record_gauges_deterministically() {
        let depth = Arc::new(AtomicU64::new(0));
        let answered = Arc::new(AtomicU64::new(0));
        let busy = BusyLanes::new(2);
        let sampler = TelemetrySampler::new(
            counter_source(&depth, &answered, &busy),
            SamplerConfig::manual(),
        );
        depth.store(3, Ordering::Relaxed);
        sampler.tick();
        depth.store(1, Ordering::Relaxed);
        answered.store(7, Ordering::Relaxed);
        sampler.tick();
        let snap = sampler.snapshot();
        assert_eq!(snap.samples.len(), 2);
        assert_eq!(snap.samples[0].tick, 0);
        assert_eq!(snap.samples[0].queue_depth, 3);
        assert_eq!(snap.samples[1].queue_depth, 1);
        assert_eq!(snap.samples[1].answered_total, 7);
        assert_eq!(snap.period_ns, 0, "manual mode advertises no period");
        // Same gauge sequence replayed on a fresh sampler → same
        // fingerprint; a diverging sequence → different fingerprint.
        let d2 = Arc::new(AtomicU64::new(0));
        let a2 = Arc::new(AtomicU64::new(0));
        let b2 = BusyLanes::new(2);
        let s2 = TelemetrySampler::new(counter_source(&d2, &a2, &b2), SamplerConfig::manual());
        d2.store(3, Ordering::Relaxed);
        s2.tick();
        d2.store(1, Ordering::Relaxed);
        a2.store(7, Ordering::Relaxed);
        s2.tick();
        assert_eq!(snap.fingerprint(), s2.snapshot().fingerprint());
        a2.store(8, Ordering::Relaxed);
        s2.tick();
        assert_ne!(snap.fingerprint(), s2.snapshot().fingerprint());
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let depth = Arc::new(AtomicU64::new(0));
        let answered = Arc::new(AtomicU64::new(0));
        let busy = BusyLanes::new(1);
        let sampler = TelemetrySampler::new(
            counter_source(&depth, &answered, &busy),
            SamplerConfig::manual().with_capacity(3),
        );
        for i in 0..8 {
            depth.store(i, Ordering::Relaxed);
            sampler.tick();
        }
        let snap = sampler.snapshot();
        assert_eq!(snap.samples.len(), 3);
        assert_eq!(snap.dropped, 5);
        assert_eq!(snap.samples.iter().map(|s| s.tick).collect::<Vec<_>>(), vec![5, 6, 7]);
        assert_eq!(snap.latest().map(|s| s.queue_depth), Some(7));
        assert_eq!(sampler.ticks(), 8);
    }

    #[test]
    fn occupancy_is_busy_over_wall_clamped() {
        let depth = Arc::new(AtomicU64::new(0));
        let answered = Arc::new(AtomicU64::new(0));
        let busy = BusyLanes::new(2);
        let sampler = TelemetrySampler::new(
            counter_source(&depth, &answered, &busy),
            SamplerConfig::manual(),
        );
        // Lane 0 claims an absurd busy delta (way beyond wall) → clamps
        // to 1.0; lane 1 stays idle → exactly 0.0.
        busy.add(0, u64::MAX / 2);
        std::thread::sleep(Duration::from_millis(2));
        sampler.tick();
        let snap = sampler.snapshot();
        let occ = &snap.latest().unwrap().occupancy;
        assert_eq!(occ.len(), 2);
        assert_eq!(occ[0], 1.0);
        assert_eq!(occ[1], 0.0);
        // Next window: both idle → both 0.
        std::thread::sleep(Duration::from_millis(2));
        sampler.tick();
        let snap = sampler.snapshot();
        assert_eq!(snap.latest().unwrap().occupancy, vec![0.0, 0.0]);
    }

    #[test]
    fn background_mode_ticks_on_its_own_and_stops() {
        let depth = Arc::new(AtomicU64::new(4));
        let answered = Arc::new(AtomicU64::new(0));
        let busy = BusyLanes::new(1);
        let sampler = TelemetrySampler::new(
            counter_source(&depth, &answered, &busy),
            SamplerConfig::default().with_period(Duration::from_millis(5)),
        );
        let deadline = Instant::now() + Duration::from_secs(5);
        while sampler.ticks() < 3 && Instant::now() < deadline {
            thread::sleep(Duration::from_millis(5));
        }
        assert!(sampler.ticks() >= 3, "background thread must tick");
        sampler.stop();
        let after = sampler.ticks();
        thread::sleep(Duration::from_millis(25));
        assert_eq!(sampler.ticks(), after, "no ticks after stop");
        assert_eq!(sampler.snapshot().latest().map(|s| s.queue_depth), Some(4));
        sampler.stop(); // idempotent
    }

    #[test]
    fn probe_runs_every_tick() {
        let depth = Arc::new(AtomicU64::new(0));
        let answered = Arc::new(AtomicU64::new(0));
        let busy = BusyLanes::new(1);
        let hits = Arc::new(AtomicU64::new(0));
        let mut source = counter_source(&depth, &answered, &busy);
        let h = Arc::clone(&hits);
        source.probe = Some(Box::new(move || {
            h.fetch_add(1, Ordering::Relaxed);
        }));
        let sampler = TelemetrySampler::new(source, SamplerConfig::manual());
        sampler.tick();
        sampler.tick();
        assert_eq!(hits.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn rolling_rates_use_the_trailing_window() {
        let depth = Arc::new(AtomicU64::new(0));
        let answered = Arc::new(AtomicU64::new(0));
        let busy = BusyLanes::new(1);
        let sampler = TelemetrySampler::new(
            counter_source(&depth, &answered, &busy),
            SamplerConfig::manual(),
        );
        sampler.tick();
        std::thread::sleep(Duration::from_millis(2));
        answered.store(100, Ordering::Relaxed);
        sampler.tick();
        let snap = sampler.snapshot();
        let rps = snap.throughput_rps(8);
        assert!(rps > 0.0, "100 answers over a real wall window");
        assert_eq!(snap.shed_rate_rps(8), 0.0);
        assert_eq!(TimelineSnapshot {
            device_names: vec![],
            samples: vec![],
            dropped: 0,
            period_ns: 0,
        }
        .throughput_rps(8), 0.0);
    }

    #[test]
    fn json_and_gauges_are_well_formed() {
        let depth = Arc::new(AtomicU64::new(2));
        let answered = Arc::new(AtomicU64::new(9));
        let busy = BusyLanes::new(2);
        let sampler = TelemetrySampler::new(
            counter_source(&depth, &answered, &busy),
            SamplerConfig::manual(),
        );
        assert_eq!(sampler.snapshot().prometheus_gauges(), "", "no gauges before any tick");
        sampler.tick();
        let text = sampler.timeline_json();
        let doc = json::JsonValue::parse(&text).expect("timeline JSON parses");
        let samples = doc.get("samples").and_then(json::JsonValue::as_arr).expect("samples");
        assert_eq!(samples.len(), 1);
        assert_eq!(
            samples[0].get("queue_depth").and_then(json::JsonValue::as_u64),
            Some(2),
        );
        let gauges = sampler.snapshot().prometheus_gauges();
        assert!(gauges.contains("npe_queue_depth 2"));
        assert!(gauges.contains("npe_in_flight 0"));
        assert!(gauges.contains("npe_pool_devices 2"));
        assert!(gauges.contains("npe_device_occupancy{device=\"0\"}"));
        assert!(gauges.contains("npe_device_occupancy{device=\"1\"}"));
        assert!(gauges.contains("npe_timeline_dropped_samples 0"));
        assert_eq!(
            samples[0].get("pool_devices").and_then(json::JsonValue::as_u64),
            Some(2),
            "samples carry the pool-size column"
        );
    }

    #[test]
    fn pool_size_changes_move_the_fingerprint() {
        let depth = Arc::new(AtomicU64::new(0));
        let answered = Arc::new(AtomicU64::new(0));
        let busy = BusyLanes::new(1);
        let pool = Arc::new(AtomicU64::new(1));
        let mut source = counter_source(&depth, &answered, &busy);
        let p = Arc::clone(&pool);
        source.pool_devices = Box::new(move || p.load(Ordering::Relaxed));
        let sampler = TelemetrySampler::new(source, SamplerConfig::manual());
        sampler.tick();
        let one = sampler.snapshot().fingerprint();
        // Same gauges, different pool size → different fingerprint: the
        // elastic e2e suite leans on this to assert resize trajectories.
        let b2 = BusyLanes::new(1);
        let mut s2 = counter_source(&depth, &answered, &b2);
        s2.pool_devices = Box::new(|| 2);
        let sampler2 = TelemetrySampler::new(s2, SamplerConfig::manual());
        sampler2.tick();
        assert_ne!(one, sampler2.snapshot().fingerprint());
        assert_eq!(sampler.snapshot().latest().map(|s| s.pool_devices), Some(1));
    }

    #[test]
    fn counter_regression_journals_once_and_rates_read_zero() {
        use crate::obs::journal::EventJournal;
        let depth = Arc::new(AtomicU64::new(0));
        let answered = Arc::new(AtomicU64::new(0));
        let busy = BusyLanes::new(1);
        let journal = EventJournal::shared(16);
        let mut source = counter_source(&depth, &answered, &busy);
        source.journal = Some(JournalSink::new(Arc::clone(&journal), None));
        let sampler = TelemetrySampler::new(source, SamplerConfig::manual());
        answered.store(100, Ordering::Relaxed);
        sampler.tick();
        std::thread::sleep(Duration::from_millis(2));
        // The counter moves backwards (sink swap / reset): exactly one
        // Warn lands in the journal, and the trailing rate reads an
        // explicit 0 instead of a saturated garbage value.
        answered.store(40, Ordering::Relaxed);
        sampler.tick();
        answered.store(10, Ordering::Relaxed);
        sampler.tick();
        let events = journal.events();
        assert_eq!(events.len(), 1, "warn-once latch");
        assert_eq!(events[0].kind, EventKind::CounterRegression);
        assert_eq!(events[0].severity, Severity::Warn);
        assert!(events[0].detail.contains("answered_total regressed 100 -> 40"));
        assert_eq!(sampler.snapshot().throughput_rps(8), 0.0, "regressed rate is explicit 0");
    }
}
