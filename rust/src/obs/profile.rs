//! Per-batch cycle/energy attribution records.
//!
//! The paper's Table-IV argument is an accounting claim: TCD-MAC wins
//! because carry-deferring moves cycles out of the steady-state rolls
//! and into one deferred completion round per GEMM. These records make
//! that split visible *per execution* instead of only in offline
//! benches: [`ExecCore`](crate::exec::ExecCore) fills one
//! [`LayerProfile`] per GEMM it walks, with one [`RoundProfile`] per
//! contiguous same-config roll run (a "round" — the unit Fig. 6C's
//! reconfiguration events delimit).
//!
//! Collection is unconditional and cheap (a handful of u64 adds per
//! roll, amortized over the backend's arithmetic); engines that run
//! untraced simply drop the [`BatchProfile`] on the floor at
//! `finish()`.

/// One contiguous run of rolls on a single NPE(K, N) configuration.
///
/// Cycle identity (asserted by the obs schema tests): per roll the MAC
/// contract charges `I` streaming cycles plus `extra` deferred-
/// completion cycles (`extra` = 1 for TCD, 0 conventional), and the
/// round boundary itself costs [`switch_cycles`](Self::switch_cycles)
/// dead cycles — so a layer's compute cycles are exactly
/// `Σ (stream_cycles + deferred_cycles)` over its rounds.
#[derive(Debug, Default, Clone, PartialEq, Eq)]
pub struct RoundProfile {
    /// The NPE(K, N) configuration the rolls ran on.
    pub config: (usize, usize),
    /// Rolls executed in this round.
    pub rolls: u64,
    /// Steady-state streaming cycles: `rolls × I`.
    pub stream_cycles: u64,
    /// Deferred-completion cycles (the TCD tail): `rolls × extra`.
    pub deferred_cycles: u64,
    /// Dead cycles paid to reconfigure into this round's config (1 in
    /// the current model — the walk counts one per config change).
    pub switch_cycles: u64,
    /// Active MAC-cycles of this round (`Σ load × (I + extra)`) — the
    /// round's share of the dynamic-energy input.
    pub active_mac_cycles: u64,
}

impl RoundProfile {
    /// Compute cycles of the round (stream + deferred, excluding the
    /// reconfiguration dead cycles).
    pub fn compute_cycles(&self) -> u64 {
        self.stream_cycles + self.deferred_cycles
    }
}

/// Attribution for one scheduled GEMM (one Γ(B, I, U) walk).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct LayerProfile {
    /// Position in the batch's execution order (0-based).
    pub index: usize,
    /// Γ batches (rows fed through the layer).
    pub batches: usize,
    /// Γ inputs (fan-in / stream length I).
    pub inputs: usize,
    /// Γ neurons (fan-out U).
    pub neurons: usize,
    /// One entry per same-config roll run, in execution order.
    pub rounds: Vec<RoundProfile>,
    /// Measured backend compute-cycle delta across the walk (equals
    /// `Σ rounds.compute_cycles()` — the schema test pins this).
    pub compute_cycles: u64,
    /// Reconfiguration dead cycles (`rounds.len()` in the current model).
    pub switch_cycles: u64,
    /// Active MAC-cycle delta (the layer's dynamic-energy share).
    pub active_mac_cycles: u64,
    /// Wall time spent resolving the schedule (cache lookup or
    /// Algorithm-1 DP), ns. 0 for pre-scheduled graph groups.
    pub mapper_wall_ns: u64,
    /// `Some(true)` = shared-cache hit, `Some(false)` = miss (DP ran),
    /// `None` = private memo or pre-scheduled (no shared cache consulted).
    pub cache_hit: Option<bool>,
    /// Wall time of the whole walk (schedule + backend + output path), ns.
    pub wall_ns: u64,
    /// SRAM weight-row reads charged to this layer (0 when the engine
    /// accounts memory at model scope instead).
    pub wmem_row_reads: u64,
    /// SRAM feature-map row reads charged to this layer.
    pub fm_row_reads: u64,
    /// SRAM feature-map row writes charged to this layer.
    pub fm_row_writes: u64,
}

impl LayerProfile {
    /// Total rolls across every round.
    pub fn rolls(&self) -> u64 {
        self.rounds.iter().map(|r| r.rolls).sum()
    }

    /// Deferred-completion cycles across every round (the TCD tail this
    /// whole subsystem exists to make visible).
    pub fn deferred_cycles(&self) -> u64 {
        self.rounds.iter().map(|r| r.deferred_cycles).sum()
    }

    /// Compute + reconfiguration cycles of the layer.
    pub fn total_cycles(&self) -> u64 {
        self.compute_cycles + self.switch_cycles
    }
}

/// Attribution for one executed batch: every GEMM the engine walked, in
/// order. Taken out of the [`ExecRun`](crate::exec::ExecRun) before
/// `finish()` by traced engines.
#[derive(Debug, Default, Clone, PartialEq)]
pub struct BatchProfile {
    pub layers: Vec<LayerProfile>,
}

impl BatchProfile {
    /// Compute + switch cycles attributed across all layers. The
    /// engine's reported total additionally includes layer-swap cycles
    /// and any non-GEMM stage costs; the Chrome exporter emits that
    /// remainder as an explicit overhead span so per-batch sums stay
    /// exact.
    pub fn attributed_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.total_cycles()).sum()
    }

    /// Total active MAC-cycles across all layers.
    pub fn active_mac_cycles(&self) -> u64 {
        self.layers.iter().map(|l| l.active_mac_cycles).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round(config: (usize, usize), rolls: u64, i: u64, extra: u64) -> RoundProfile {
        RoundProfile {
            config,
            rolls,
            stream_cycles: rolls * i,
            deferred_cycles: rolls * extra,
            switch_cycles: 1,
            active_mac_cycles: rolls * (i + extra) * (config.0 * config.1) as u64,
        }
    }

    #[test]
    fn cycle_identities_hold() {
        let r = round((4, 2), 3, 10, 1);
        assert_eq!(r.compute_cycles(), 33);
        let layer = LayerProfile {
            index: 0,
            batches: 4,
            inputs: 10,
            neurons: 6,
            rounds: vec![round((4, 2), 3, 10, 1), round((2, 4), 2, 10, 1)],
            compute_cycles: 33 + 22,
            switch_cycles: 2,
            ..Default::default()
        };
        assert_eq!(layer.rolls(), 5);
        assert_eq!(layer.deferred_cycles(), 5);
        assert_eq!(
            layer.compute_cycles,
            layer.rounds.iter().map(|r| r.compute_cycles()).sum::<u64>()
        );
        assert_eq!(layer.total_cycles(), 57);
        let batch = BatchProfile { layers: vec![layer.clone(), layer] };
        assert_eq!(batch.attributed_cycles(), 114);
    }
}
