//! [`LogHistogram`] — a constant-memory log-bucketed latency histogram.
//!
//! Replaces the coordinator's old ring buffer + clone-and-sort
//! percentile path: recording is O(1) (a leading-zeros shift and one
//! array increment), a quantile is O(buckets), and `render()` no longer
//! clones a 128 Ki-entry `Vec` per call. The trade is exactness for
//! bounded relative error: values below [`LINEAR_MAX`] land in exact
//! unit buckets; above it each power-of-two range is split into
//! [`SUB_BUCKETS`] linear sub-buckets, so a reported quantile is within
//! ±(1 / 2·SUB_BUCKETS) ≈ 1.6 % of the true sample (≤ 3.2 % worst
//! case at bucket edges).

/// Sub-buckets per power-of-two range (relative error ≤ 1/32 ≈ 3.1 %).
pub const SUB_BUCKETS: u64 = 32;
/// Values below this are counted exactly (one bucket per value).
pub const LINEAR_MAX: u64 = SUB_BUCKETS;
/// log2(SUB_BUCKETS).
const SUB_SHIFT: u32 = SUB_BUCKETS.trailing_zeros();
/// Total bucket count covering the full u64 range.
const BUCKETS: usize = (LINEAR_MAX + (64 - SUB_SHIFT as u64) * SUB_BUCKETS) as usize;

/// Log-bucketed histogram over `u64` samples (the coordinator feeds it
/// wall latencies in ns). Constant memory, O(1) record, O(buckets)
/// quantile.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct LogHistogram {
    /// Lazily sized to [`BUCKETS`] on first record, so
    /// `CoordinatorMetrics::default()` stays allocation-free.
    counts: Vec<u64>,
    count: u64,
    /// Exact running sum (Prometheus `_sum`; u128 so a years-long run of
    /// ns samples cannot overflow).
    sum: u128,
    /// Exact extrema (the tails are what dashboards read off p99/p100).
    min: u64,
    max: u64,
}

/// Bucket index of a sample.
#[inline]
fn bucket_of(v: u64) -> usize {
    if v < LINEAR_MAX {
        v as usize
    } else {
        let msb = 63 - v.leading_zeros();
        let sub = (v >> (msb - SUB_SHIFT)) - SUB_BUCKETS;
        (LINEAR_MAX + (msb - SUB_SHIFT) as u64 * SUB_BUCKETS + sub) as usize
    }
}

/// Midpoint representative value of a bucket.
#[inline]
fn bucket_mid(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < LINEAR_MAX {
        idx
    } else {
        let major = (idx - LINEAR_MAX) / SUB_BUCKETS + SUB_SHIFT as u64;
        let sub = (idx - LINEAR_MAX) % SUB_BUCKETS;
        let lower = (1u64 << major) + (sub << (major - SUB_SHIFT as u64));
        let width = 1u64 << (major - SUB_SHIFT as u64);
        lower + width / 2
    }
}

/// Exclusive upper bound of a bucket (for Prometheus `le` edges).
#[inline]
fn bucket_upper(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < LINEAR_MAX {
        idx + 1
    } else {
        let major = (idx - LINEAR_MAX) / SUB_BUCKETS + SUB_SHIFT as u64;
        let sub = (idx - LINEAR_MAX) % SUB_BUCKETS;
        (1u64 << major) + ((sub + 1) << (major - SUB_SHIFT as u64))
    }
}

impl LogHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one sample. O(1).
    pub fn record(&mut self, v: u64) {
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
        }
        self.counts[bucket_of(v)] += 1;
        if self.count == 0 || v < self.min {
            self.min = v;
        }
        if v > self.max {
            self.max = v;
        }
        self.sum += v as u128;
        self.count += 1;
    }

    /// Samples recorded so far.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact sum of all recorded samples.
    pub fn sum(&self) -> u128 {
        self.sum
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    /// Largest sample seen (exact).
    pub fn max(&self) -> u64 {
        self.max
    }

    /// Smallest sample seen (exact); `None` when nothing has been
    /// recorded — the internal `0` sentinel would otherwise read as a
    /// real observed sample.
    pub fn min(&self) -> Option<u64> {
        if self.count == 0 {
            None
        } else {
            Some(self.min)
        }
    }

    /// The largest value sharing `v`'s bucket — the *top* of the bucket
    /// containing `v`. [`count_le`](Self::count_le) is exact at exactly
    /// these values, so callers that must compare against an arbitrary
    /// threshold (the SLO tracker's latency objective, say) snap the
    /// threshold up to `bucket_top(threshold)` once and get exact counts
    /// ever after. Below [`LINEAR_MAX`] every value tops its own unit
    /// bucket, so `bucket_top(v) == v` there.
    pub fn bucket_top(v: u64) -> u64 {
        let idx = bucket_of(v) as u64;
        if idx < LINEAR_MAX {
            return idx;
        }
        let major = (idx - LINEAR_MAX) / SUB_BUCKETS + SUB_SHIFT as u64;
        let sub = (idx - LINEAR_MAX) % SUB_BUCKETS;
        // u128: the top bucket's exclusive upper bound is 2^64.
        let upper = (1u128 << major) + (u128::from(sub + 1) << (major - SUB_SHIFT as u64));
        u64::try_from(upper - 1).unwrap_or(u64::MAX)
    }

    /// Number of recorded samples ≤ `v`, computed as the cumulative
    /// count through the bucket containing `v` (clamped by the exact
    /// extrema). Exact whenever `v` is the top value of its bucket —
    /// always true below [`LINEAR_MAX`] and at sub-bucket-aligned edges
    /// (e.g. any multiple of `2^(k-5)` within the `[2^k, 2^(k+1))`
    /// range); otherwise it over-counts by at most the one partial
    /// bucket, i.e. stays within the histogram's ~3 % bucket error.
    pub fn count_le(&self, v: u64) -> u64 {
        if self.count == 0 || v < self.min {
            return 0;
        }
        if v >= self.max {
            return self.count;
        }
        self.counts.iter().take(bucket_of(v) + 1).sum()
    }

    /// Nearest-rank quantile, `p` in [0, 100]: the representative
    /// (midpoint) value of the bucket holding the rank-⌈p/100·n⌉ sample,
    /// clamped to the exact observed extrema. 0 when empty. O(buckets).
    pub fn quantile(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p / 100.0) * self.count as f64).ceil() as u64;
        let rank = rank.clamp(1, self.count);
        let mut seen = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= rank {
                return bucket_mid(idx).clamp(self.min, self.max);
            }
        }
        self.max
    }

    /// Non-empty buckets as `(upper_bound, cumulative_count)` pairs —
    /// the Prometheus classic-histogram exposition shape. The final
    /// entry's cumulative count equals [`count`](Self::count).
    pub fn cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::new();
        let mut cum = 0u64;
        for (idx, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                cum += c;
                out.push((bucket_upper(idx), cum));
            }
        }
        out
    }

    /// Fixed power-of-two bucket ladder as `(le_edge, cumulative_count)`
    /// pairs — every scrape emits the *same* 64 edges (`2^0 ..= 2^63`),
    /// so PromQL `histogram_quantile` sees a stable `le` set over time
    /// (the non-empty-only shape of
    /// [`cumulative_buckets`](Self::cumulative_buckets) changes between
    /// scrapes as new buckets fill, which breaks rate windows). Each
    /// edge's count covers the samples strictly below it, matching the
    /// exclusive-upper-bound convention of the underlying buckets;
    /// power-of-two edges are always bucket boundaries, so the counts
    /// are exact. Samples at or above `2^63` appear only in the `+Inf`
    /// total the exposition layer adds.
    pub fn stable_cumulative_buckets(&self) -> Vec<(u64, u64)> {
        let mut out = Vec::with_capacity(64);
        let mut cum = 0u64;
        let mut idx = 0usize;
        for k in 0..64u32 {
            let edge = 1u64 << k;
            while idx < self.counts.len() && bucket_upper(idx) <= edge {
                cum += self.counts[idx];
                idx += 1;
            }
            out.push((edge, cum));
        }
        out
    }

    /// Fold another histogram into this one (fleet lane merges).
    pub fn merge(&mut self, other: &LogHistogram) {
        if other.count == 0 {
            return;
        }
        if self.counts.is_empty() {
            self.counts = vec![0; BUCKETS];
        }
        for (a, &b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        if self.count == 0 || other.min < self.min {
            self.min = other.min;
        }
        self.max = self.max.max(other.max);
        self.sum += other.sum;
        self.count += other.count;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_values_are_exact() {
        let mut h = LogHistogram::new();
        for v in 0..LINEAR_MAX {
            h.record(v);
        }
        assert_eq!(h.count(), LINEAR_MAX);
        // Every value below LINEAR_MAX has its own bucket.
        for v in 0..LINEAR_MAX {
            let p = (v + 1) as f64 / LINEAR_MAX as f64 * 100.0;
            assert_eq!(h.quantile(p), v, "exact unit bucket for {v}");
        }
    }

    #[test]
    fn quantiles_within_bucket_error() {
        // 1..=100 µs in ns — the old nearest-rank test, under the new
        // bucket-relative error bound (±3.2 % worst case).
        let mut h = LogHistogram::new();
        for v in 1..=100u64 {
            h.record(v * 1000);
        }
        for (p, want) in [(50.0, 50_000.0), (95.0, 95_000.0), (99.0, 99_000.0)] {
            let got = h.quantile(p) as f64;
            let err = (got - want).abs() / want;
            assert!(err <= 0.04, "p{p}: got {got}, want {want} (err {err:.3})");
        }
        // Extrema are exact, so p100 is too.
        assert_eq!(h.quantile(100.0), 100_000);
        assert_eq!(h.min(), Some(1000));
        assert_eq!(h.max(), 100_000);
    }

    #[test]
    fn empty_is_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.quantile(99.0), 0);
        assert_eq!(h.count(), 0);
        assert!(h.cumulative_buckets().is_empty());
    }

    #[test]
    fn empty_min_is_none_not_zero() {
        // Regression: the Default sentinel used to leak out as a real
        // observed sample of 0.
        let mut h = LogHistogram::new();
        assert_eq!(h.min(), None);
        h.record(7);
        assert_eq!(h.min(), Some(7));
    }

    #[test]
    fn bucket_top_is_the_exactness_point_of_count_le() {
        // Unit buckets: every small value tops itself.
        for v in 0..LINEAR_MAX {
            assert_eq!(LogHistogram::bucket_top(v), v);
        }
        // Above LINEAR_MAX: the top is one below the next bucket's lower
        // bound, and everything in the bucket shares it.
        assert_eq!(LogHistogram::bucket_top(50_000), 50_175, "bucket [49152, 50176)");
        assert_eq!(LogHistogram::bucket_top(49_152), 50_175);
        assert_eq!(LogHistogram::bucket_top(50_175), 50_175, "idempotent at the top");
        assert_eq!(LogHistogram::bucket_top(50_176), 51_199, "next bucket");
        // The final bucket's upper bound is 2^64; the top saturates.
        assert_eq!(LogHistogram::bucket_top(u64::MAX), u64::MAX);
        // count_le at the snapped value counts the whole bucket exactly.
        let mut h = LogHistogram::new();
        h.record(49_200);
        h.record(50_100);
        h.record(50_176);
        assert_eq!(h.count_le(LogHistogram::bucket_top(50_000)), 2);
    }

    #[test]
    fn count_le_is_exact_at_bucket_tops() {
        let mut h = LogHistogram::new();
        // 10 samples below LINEAR_MAX (exact unit buckets), 5 above.
        for v in 1..=10u64 {
            h.record(v);
        }
        for _ in 0..5 {
            h.record(1 << 20);
        }
        assert_eq!(h.count_le(0), 0);
        assert_eq!(h.count_le(5), 5);
        assert_eq!(h.count_le(10), 10);
        // (1 << 21) - 1 tops its bucket ladder; everything is below it.
        assert_eq!(h.count_le((1 << 21) - 1), 15);
        assert_eq!(h.count_le(u64::MAX), 15);
        assert_eq!(LogHistogram::new().count_le(u64::MAX), 0);
    }

    #[test]
    fn stable_buckets_are_stable_and_cover_the_count() {
        let mut h = LogHistogram::new();
        let empty_edges: Vec<u64> =
            LogHistogram::new().stable_cumulative_buckets().iter().map(|b| b.0).collect();
        for v in [3u64, 100, 100, 5_000, 1 << 40] {
            h.record(v);
        }
        let buckets = h.stable_cumulative_buckets();
        // The `le` edge set is identical regardless of what was recorded.
        let edges: Vec<u64> = buckets.iter().map(|b| b.0).collect();
        assert_eq!(edges, empty_edges, "edge set must not depend on the data");
        assert_eq!(edges.len(), 64);
        assert_eq!(edges[0], 1);
        assert_eq!(edges[63], 1 << 63);
        // Counts are exact at power-of-two edges and reach the total.
        assert_eq!(buckets.last().unwrap().1, h.count());
        let at = |e: u64| buckets.iter().find(|b| b.0 == e).unwrap().1;
        assert_eq!(at(4), 1, "only 3 is below 4");
        assert_eq!(at(128), 3, "3 and the two 100s");
        assert_eq!(at(8192), 4);
        assert_eq!(at(1 << 41), 5);
        for w in buckets.windows(2) {
            assert!(w[0].0 < w[1].0 && w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn order_independent() {
        let mut asc = LogHistogram::new();
        let mut desc = LogHistogram::new();
        for v in 1..=1000u64 {
            asc.record(v * 17);
            desc.record((1001 - v) * 17);
        }
        for p in [10.0, 50.0, 90.0, 99.0] {
            assert_eq!(asc.quantile(p), desc.quantile(p));
        }
    }

    #[test]
    fn cumulative_buckets_cover_everything() {
        let mut h = LogHistogram::new();
        for v in [3u64, 100, 100, 5_000, 1 << 40] {
            h.record(v);
        }
        let buckets = h.cumulative_buckets();
        assert_eq!(buckets.last().unwrap().1, 5, "cumulative tail == count");
        // Cumulative counts are non-decreasing, upper bounds strictly grow.
        for w in buckets.windows(2) {
            assert!(w[0].0 < w[1].0);
            assert!(w[0].1 <= w[1].1);
        }
    }

    #[test]
    fn merge_equals_combined_recording() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut all = LogHistogram::new();
        for v in 1..=500u64 {
            a.record(v * 7);
            all.record(v * 7);
        }
        for v in 1..=500u64 {
            b.record(v * 13);
            all.record(v * 13);
        }
        a.merge(&b);
        assert_eq!(a, all);
    }

    #[test]
    fn huge_values_do_not_overflow() {
        let mut h = LogHistogram::new();
        h.record(u64::MAX);
        h.record(0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.quantile(0.0), 0);
    }
}
