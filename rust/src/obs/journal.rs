//! [`EventJournal`] — a bounded, structured log of notable serving
//! events (device lost, load shed, admission reject, cache eviction,
//! SLO budget exhausted).
//!
//! The journal is the "what happened and when" companion to the
//! timeline's "how did the gauges move": metrics tell you the shed rate
//! spiked, the journal tells you which tenant was shedding and why.
//! Events carry a monotonic sequence number (assigned under the ring
//! lock, so sequence order == insertion order), a wall timestamp, a
//! severity, and an optional tenant label. The ring is fixed-capacity:
//! on overflow the *oldest* event is dropped and a drop counter bumps,
//! so the journal can never grow without bound and never lies about
//! having seen everything.
//!
//! Writers hold [`JournalSink`] handles — a cheap clone of the shared
//! journal pre-labelled with the writer's tenant — so the hot paths
//! (admission refusal, shed resolution, device-thread exit) append
//! without knowing who else shares the ring.

use crate::util::lock;
use std::collections::VecDeque;
use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

/// How loud an event is. Ordered: `Info < Warn < Error`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Info,
    Warn,
    Error,
}

impl fmt::Display for Severity {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Severity::Info => "INFO",
            Severity::Warn => "WARN",
            Severity::Error => "ERROR",
        })
    }
}

/// What class of event happened. The set mirrors the serving layer's
/// failure/pressure surfaces; stringly-typed details ride alongside in
/// [`JournalEvent::detail`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    /// A device thread died (join failure at shutdown, short batch
    /// output at dispatch).
    DeviceLost,
    /// `ShedOldest` admission dropped queued work to admit newer work.
    Shed,
    /// `Reject` admission refused a submit at the depth bound.
    AdmissionReject,
    /// The shared schedule cache evicted an entry under its LRU bound.
    CacheEviction,
    /// A tenant's SLO error budget crossed exhaustion (burn ≥ budget).
    SloBudgetExhausted,
    /// The elastic pool controller resized the device pool (grow,
    /// shrink, dead-device backfill, or an operator-forced resize).
    PoolResize,
    /// A cumulative telemetry counter moved backwards (metrics-sink swap
    /// or reset); trailing rates read 0 until the window clears it.
    CounterRegression,
    /// The dataflow autotuner chose a per-layer plan for a served model
    /// (detail carries the lane summary, e.g. `os→os→nlr`, and the
    /// predicted totals).
    DataflowPlan,
}

impl fmt::Display for EventKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            EventKind::DeviceLost => "device_lost",
            EventKind::Shed => "shed",
            EventKind::AdmissionReject => "admission_reject",
            EventKind::CacheEviction => "cache_eviction",
            EventKind::SloBudgetExhausted => "slo_budget_exhausted",
            EventKind::PoolResize => "pool_resize",
            EventKind::CounterRegression => "counter_regression",
            EventKind::DataflowPlan => "dataflow_plan",
        })
    }
}

/// One journal entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JournalEvent {
    /// Monotonic sequence number, unique across the journal's lifetime.
    /// Later events always carry larger sequence numbers, so consumers
    /// can detect the gap left by dropped events.
    pub seq: u64,
    /// Wall-clock timestamp, ns since the Unix epoch.
    pub wall_ns: u64,
    pub severity: Severity,
    pub kind: EventKind,
    /// Tenant the event belongs to; `None` for fleet-wide events.
    pub tenant: Option<String>,
    /// Free-form human-readable detail, e.g. `"depth 64 at bound"`.
    pub detail: String,
}

impl JournalEvent {
    /// One-line log form: `#seq LEVEL kind [tenant] detail`.
    pub fn render(&self) -> String {
        match &self.tenant {
            Some(t) => format!(
                "#{} {} {} [{}] {}",
                self.seq, self.severity, self.kind, t, self.detail
            ),
            None => format!("#{} {} {} {}", self.seq, self.severity, self.kind, self.detail),
        }
    }
}

struct Ring {
    events: VecDeque<JournalEvent>,
    next_seq: u64,
}

/// Bounded structured event log. Cheap to append (one short critical
/// section), safe to share (`Arc`), and honest about loss (dropped
/// count).
pub struct EventJournal {
    ring: Mutex<Ring>,
    capacity: usize,
    dropped: AtomicU64,
}

impl EventJournal {
    /// A journal holding at most `capacity` events (≥ 1 enforced).
    pub fn new(capacity: usize) -> Self {
        let capacity = capacity.max(1);
        Self {
            ring: Mutex::new(Ring { events: VecDeque::with_capacity(capacity), next_seq: 0 }),
            capacity,
            dropped: AtomicU64::new(0),
        }
    }

    /// Shared-ownership constructor for multi-writer wiring.
    pub fn shared(capacity: usize) -> Arc<Self> {
        Arc::new(Self::new(capacity))
    }

    /// Append one event; drops the oldest (and counts the drop) when
    /// the ring is full. Returns the assigned sequence number.
    pub fn push(
        &self,
        kind: EventKind,
        severity: Severity,
        tenant: Option<&str>,
        detail: impl Into<String>,
    ) -> u64 {
        let wall_ns = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let mut ring = lock(&self.ring);
        let seq = ring.next_seq;
        ring.next_seq += 1;
        if ring.events.len() == self.capacity {
            ring.events.pop_front();
            self.dropped.fetch_add(1, Ordering::Relaxed);
        }
        ring.events.push_back(JournalEvent {
            seq,
            wall_ns,
            severity,
            kind,
            tenant: tenant.map(str::to_owned),
            detail: detail.into(),
        });
        seq
    }

    /// Every retained event, oldest first.
    pub fn events(&self) -> Vec<JournalEvent> {
        lock(&self.ring).events.iter().cloned().collect()
    }

    /// Retained events for one tenant, oldest first. Fleet-wide events
    /// (no tenant label) are *not* included.
    pub fn events_for(&self, tenant: &str) -> Vec<JournalEvent> {
        lock(&self.ring)
            .events
            .iter()
            .filter(|e| e.tenant.as_deref() == Some(tenant))
            .cloned()
            .collect()
    }

    /// The newest `n` retained events, oldest first.
    pub fn tail(&self, n: usize) -> Vec<JournalEvent> {
        let ring = lock(&self.ring);
        let skip = ring.events.len().saturating_sub(n);
        ring.events.iter().skip(skip).cloned().collect()
    }

    /// Events dropped to overflow so far.
    pub fn dropped(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Retained event count.
    pub fn len(&self) -> usize {
        lock(&self.ring).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Ring capacity.
    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

impl fmt::Debug for EventJournal {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("EventJournal")
            .field("capacity", &self.capacity)
            .field("len", &self.len())
            .field("dropped", &self.dropped())
            .finish()
    }
}

/// A writer handle: the shared journal plus the writer's tenant label.
/// Clone freely — every serving-layer hook takes one of these so the
/// hot path appends one labelled event without string plumbing.
#[derive(Clone, Debug)]
pub struct JournalSink {
    journal: Arc<EventJournal>,
    tenant: Option<String>,
}

impl JournalSink {
    pub fn new(journal: Arc<EventJournal>, tenant: Option<&str>) -> Self {
        Self { journal, tenant: tenant.map(str::to_owned) }
    }

    /// Append one event under this sink's tenant label.
    pub fn event(&self, kind: EventKind, severity: Severity, detail: impl Into<String>) {
        self.journal.push(kind, severity, self.tenant.as_deref(), detail);
    }

    /// The shared journal behind this sink.
    pub fn journal(&self) -> &Arc<EventJournal> {
        &self.journal
    }

    /// This sink's tenant label.
    pub fn tenant(&self) -> Option<&str> {
        self.tenant.as_deref()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequence_numbers_are_monotonic_and_dense() {
        let j = EventJournal::new(8);
        for i in 0..5 {
            let seq = j.push(EventKind::Shed, Severity::Warn, None, format!("e{i}"));
            assert_eq!(seq, i);
        }
        let evs = j.events();
        assert_eq!(evs.len(), 5);
        for (i, e) in evs.iter().enumerate() {
            assert_eq!(e.seq, i as u64);
        }
        assert_eq!(j.dropped(), 0);
    }

    #[test]
    fn overflow_drops_oldest_and_counts() {
        let j = EventJournal::new(3);
        for i in 0..7 {
            j.push(EventKind::AdmissionReject, Severity::Warn, None, format!("e{i}"));
        }
        let evs = j.events();
        assert_eq!(evs.len(), 3, "ring stays at capacity");
        // The *newest* three survive; sequence numbers show the gap.
        assert_eq!(evs.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![4, 5, 6]);
        assert_eq!(evs[0].detail, "e4");
        assert_eq!(j.dropped(), 4, "every displaced event is counted");
    }

    #[test]
    fn per_tenant_query_filters() {
        let j = EventJournal::shared(16);
        let iris = JournalSink::new(Arc::clone(&j), Some("iris"));
        let lenet = JournalSink::new(Arc::clone(&j), Some("lenet"));
        let fleet = JournalSink::new(Arc::clone(&j), None);
        iris.event(EventKind::AdmissionReject, Severity::Warn, "full");
        lenet.event(EventKind::Shed, Severity::Warn, "shed 2");
        iris.event(EventKind::SloBudgetExhausted, Severity::Warn, "burn 1.2");
        fleet.event(EventKind::DeviceLost, Severity::Error, "device 3");
        assert_eq!(j.events_for("iris").len(), 2);
        assert_eq!(j.events_for("lenet").len(), 1);
        assert_eq!(j.events_for("nope").len(), 0);
        assert_eq!(j.len(), 4);
        // Fleet-wide events have no tenant and only appear in events().
        assert!(j.events().iter().any(|e| e.kind == EventKind::DeviceLost));
        assert!(j.events_for("iris").iter().all(|e| e.tenant.as_deref() == Some("iris")));
    }

    #[test]
    fn tail_returns_newest_in_order() {
        let j = EventJournal::new(10);
        for i in 0..6 {
            j.push(EventKind::CacheEviction, Severity::Info, None, format!("e{i}"));
        }
        let t = j.tail(2);
        assert_eq!(t.iter().map(|e| e.seq).collect::<Vec<_>>(), vec![4, 5]);
        assert_eq!(j.tail(100).len(), 6);
    }

    #[test]
    fn render_is_one_line_and_labelled() {
        let j = EventJournal::new(4);
        j.push(EventKind::Shed, Severity::Warn, Some("iris"), "dropped 3 queued");
        let e = &j.events()[0];
        let line = e.render();
        assert!(line.contains("WARN"));
        assert!(line.contains("shed"));
        assert!(line.contains("[iris]"));
        assert!(line.contains("dropped 3 queued"));
        assert!(!line.contains('\n'));
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let j = EventJournal::new(0);
        assert_eq!(j.capacity(), 1);
        j.push(EventKind::Shed, Severity::Warn, None, "a");
        j.push(EventKind::Shed, Severity::Warn, None, "b");
        assert_eq!(j.len(), 1);
        assert_eq!(j.events()[0].detail, "b");
        assert_eq!(j.dropped(), 1);
    }
}
