//! Metrics snapshot + Prometheus-style / JSON exposition.
//!
//! [`MetricsSnapshot`] joins the coordinator counters (with the shared
//! cache overlaid — the one consistent read the PR-6 cache-race fix
//! mandates) with per-layer attribution aggregated from the trace log.
//! Reachable from
//! [`NpeService::metrics_snapshot`](crate::serve::NpeService::metrics_snapshot)
//! and the CLI `obs` subcommand.

use super::span::TraceLog;
use crate::coordinator::CoordinatorMetrics;
use crate::util::json::escape;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Aggregated attribution for one layer position across every traced
/// batch. Keyed by execution index within a batch — when one tracer is
/// shared across services serving *different* models, aggregate per
/// service instead (each service snapshots its own metrics).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct LayerAgg {
    pub index: usize,
    /// Batches that executed this layer.
    pub batches: u64,
    /// Same-config rounds.
    pub rounds: u64,
    pub rolls: u64,
    pub stream_cycles: u64,
    /// The TCD deferred-completion tail, summed.
    pub deferred_cycles: u64,
    pub switch_cycles: u64,
    pub active_mac_cycles: u64,
    /// PE dynamic energy attributed to this layer (each batch's
    /// `pe_dynamic_pj` split proportionally to active MAC-cycles; the
    /// leak/memory components stay batch-level and are not re-split).
    pub pe_dynamic_pj: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// One consistent observability read: coordinator counters + per-layer
/// attribution + trace health.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Coordinator counters, cache stats already overlaid.
    pub metrics: CoordinatorMetrics,
    /// Per-layer attribution (empty when the service runs untraced).
    pub layers: Vec<LayerAgg>,
    /// Trace events lost to buffer bounds (0 in healthy runs).
    pub dropped_events: u64,
    /// Tenant this snapshot belongs to, when taken through a
    /// [`ModelRegistry`](crate::serve::ModelRegistry): the Prometheus
    /// exposition then carries `tenant="<name>"` on every sample and the
    /// JSON object a `tenant` field. `None` for a standalone service.
    pub tenant: Option<String>,
}

/// Aggregate per-layer attribution out of a trace snapshot.
pub fn aggregate_layers(log: &TraceLog) -> Vec<LayerAgg> {
    let mut by_index: BTreeMap<usize, LayerAgg> = BTreeMap::new();
    for b in &log.batches {
        let total_amc: u64 = b.profile.layers.iter().map(|l| l.active_mac_cycles).sum();
        for layer in &b.profile.layers {
            let agg = by_index.entry(layer.index).or_insert_with(|| LayerAgg {
                index: layer.index,
                ..Default::default()
            });
            agg.batches += 1;
            agg.rounds += layer.rounds.len() as u64;
            agg.rolls += layer.rolls();
            agg.stream_cycles += layer.rounds.iter().map(|r| r.stream_cycles).sum::<u64>();
            agg.deferred_cycles += layer.deferred_cycles();
            agg.switch_cycles += layer.switch_cycles;
            agg.active_mac_cycles += layer.active_mac_cycles;
            if total_amc > 0 {
                agg.pe_dynamic_pj +=
                    b.pe_dynamic_pj * layer.active_mac_cycles as f64 / total_amc as f64;
            }
            match layer.cache_hit {
                Some(true) => agg.cache_hits += 1,
                Some(false) => agg.cache_misses += 1,
                None => {}
            }
        }
    }
    by_index.into_values().collect()
}

impl MetricsSnapshot {
    /// Build a snapshot from already-overlaid metrics and an optional
    /// trace log.
    pub fn new(metrics: CoordinatorMetrics, log: Option<&TraceLog>) -> Self {
        Self {
            layers: log.map(aggregate_layers).unwrap_or_default(),
            dropped_events: log.map(|l| l.dropped_events).unwrap_or(0),
            metrics,
            tenant: None,
        }
    }

    /// Label this snapshot with a tenant name (builder form — the
    /// registry applies it when snapshotting per tenant).
    pub fn with_tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = Some(tenant.into());
        self
    }

    /// Prometheus text exposition (classic format: `# TYPE` headers,
    /// counters/gauges, a classic histogram for wall latency, per-layer
    /// labeled attribution series).
    pub fn prometheus_text(&self) -> String {
        let m = &self.metrics;
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", num(v));
        };
        counter("npe_requests_total", "Requests dispatched to a device.", m.requests as f64);
        counter("npe_rejected_requests_total", "Bad-shape refusals.", m.rejected_requests as f64);
        counter("npe_shed_requests_total", "Admission-control sheds.", m.shed_requests as f64);
        counter("npe_responses_dropped_total", "Dropped responses.", m.responses_dropped as f64);
        counter("npe_batches_total", "Batches executed.", m.batches as f64);
        counter("npe_padded_slots_total", "Padding rows added to batches.", m.padded_slots as f64);
        counter("npe_verified_batches_total", "PJRT-verified batches.", m.verified_batches as f64);
        counter("npe_verify_mismatches_total", "PJRT mismatches.", m.verify_mismatches as f64);
        counter("npe_sim_time_ns_total", "Simulated NPE time, ns.", m.sim_time_ns);
        counter("npe_sim_energy_pj_total", "Simulated NPE energy, pJ.", m.sim_energy_pj);
        counter("npe_cache_hits_total", "Schedule-cache hits.", m.cache_hits as f64);
        counter("npe_cache_misses_total", "Schedule-cache misses.", m.cache_misses as f64);
        counter("npe_cache_evictions_total", "Cache LRU evictions.", m.cache_evictions as f64);
        counter("npe_trace_dropped_events_total", "Trace events lost.", self.dropped_events as f64);

        let _ = writeln!(out, "# HELP npe_queue_peak Deepest the work queue ever got.");
        let _ = writeln!(out, "# TYPE npe_queue_peak gauge");
        let _ = writeln!(out, "npe_queue_peak {}", m.queue_peak);

        // Wall latency as a classic histogram, in µs.
        let _ = writeln!(out, "# HELP npe_latency_us Wall latency submit to response, us.");
        let _ = writeln!(out, "# TYPE npe_latency_us histogram");
        for (upper_ns, cum) in m.latencies.cumulative_buckets() {
            let _ = writeln!(
                out,
                "npe_latency_us_bucket{{le=\"{}\"}} {cum}",
                num(upper_ns as f64 / 1e3)
            );
        }
        let _ = writeln!(out, "npe_latency_us_bucket{{le=\"+Inf\"}} {}", m.latencies.count());
        let _ = writeln!(out, "npe_latency_us_sum {}", num(m.latencies.sum() as f64 / 1e3));
        let _ = writeln!(out, "npe_latency_us_count {}", m.latencies.count());

        // Per-device lanes.
        let _ = writeln!(out, "# HELP npe_device_requests_total Requests per device lane.");
        let _ = writeln!(out, "# TYPE npe_device_requests_total counter");
        for (i, d) in m.devices.iter().enumerate() {
            let _ = writeln!(
                out,
                "npe_device_requests_total{{device=\"{i}\",geometry=\"{}\"}} {}",
                escape(&d.geometry),
                d.requests
            );
        }

        // Per-layer attribution.
        let series: [(&str, &str, fn(&LayerAgg) -> f64); 6] = [
            ("npe_layer_rolls_total", "Rolls executed per layer.", |l| l.rolls as f64),
            ("npe_layer_rounds_total", "Same-config rounds per layer.", |l| l.rounds as f64),
            ("npe_layer_stream_cycles_total", "Streaming cycles.", |l| l.stream_cycles as f64),
            ("npe_layer_deferred_cycles_total", "TCD tail cycles.", |l| l.deferred_cycles as f64),
            ("npe_layer_switch_cycles_total", "Reconfig dead cycles.", |l| l.switch_cycles as f64),
            ("npe_layer_pe_dynamic_pj_total", "PE dynamic energy, pJ.", |l| l.pe_dynamic_pj),
        ];
        for (name, help, get) in series {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            for l in &self.layers {
                let _ = writeln!(out, "{name}{{layer=\"{}\"}} {}", l.index, num(get(l)));
            }
        }
        match &self.tenant {
            None => out,
            Some(tenant) => inject_tenant_label(&out, tenant),
        }
    }

    /// The snapshot as one JSON object (hand-rolled, same idiom as the
    /// bench writers).
    pub fn to_json(&self) -> String {
        let m = &self.metrics;
        let mut layers = String::new();
        for (i, l) in self.layers.iter().enumerate() {
            if i > 0 {
                layers.push(',');
            }
            let _ = write!(
                layers,
                "{{\"index\":{},\"batches\":{},\"rounds\":{},\"rolls\":{},\
                 \"stream_cycles\":{},\"deferred_cycles\":{},\"switch_cycles\":{},\
                 \"active_mac_cycles\":{},\"pe_dynamic_pj\":{:.3},\
                 \"cache_hits\":{},\"cache_misses\":{}}}",
                l.index,
                l.batches,
                l.rounds,
                l.rolls,
                l.stream_cycles,
                l.deferred_cycles,
                l.switch_cycles,
                l.active_mac_cycles,
                l.pe_dynamic_pj,
                l.cache_hits,
                l.cache_misses,
            );
        }
        let mut devices = String::new();
        for (i, d) in m.devices.iter().enumerate() {
            if i > 0 {
                devices.push(',');
            }
            let _ = write!(
                devices,
                "{{\"device\":{i},\"geometry\":\"{}\",\"batches\":{},\"requests\":{},\
                 \"sim_busy_ns\":{:.3}}}",
                escape(&d.geometry),
                d.batches,
                d.requests,
                d.sim_busy_ns,
            );
        }
        let tenant = match &self.tenant {
            Some(t) => format!("\"{}\"", escape(t)),
            None => "null".to_string(),
        };
        format!(
            "{{\"tenant\":{tenant},\
             \"requests\":{},\"rejected_requests\":{},\"shed_requests\":{},\
             \"responses_dropped\":{},\"batches\":{},\"padded_slots\":{},\
             \"verified_batches\":{},\"verify_mismatches\":{},\
             \"sim_time_ns\":{:.3},\"sim_energy_pj\":{:.3},\
             \"cache_hits\":{},\"cache_misses\":{},\"cache_evictions\":{},\
             \"queue_peak\":{},\"latencies_recorded\":{},\
             \"wall_p50_us\":{:.3},\"wall_p95_us\":{:.3},\"wall_p99_us\":{:.3},\
             \"dropped_events\":{},\"devices\":[{devices}],\"layers\":[{layers}]}}\n",
            m.requests,
            m.rejected_requests,
            m.shed_requests,
            m.responses_dropped,
            m.batches,
            m.padded_slots,
            m.verified_batches,
            m.verify_mismatches,
            m.sim_time_ns,
            m.sim_energy_pj,
            m.cache_hits,
            m.cache_misses,
            m.cache_evictions,
            m.queue_peak,
            m.latencies_recorded,
            m.p50_us(),
            m.p95_us(),
            m.p99_us(),
            self.dropped_events,
        )
    }
}

/// Inject `tenant="<name>"` into every sample line of a Prometheus
/// exposition: bare names gain a label set, labeled names gain a first
/// label. Comment lines (`# HELP` / `# TYPE`) pass through untouched.
fn inject_tenant_label(text: &str, tenant: &str) -> String {
    let label = format!("tenant=\"{}\"", escape(tenant));
    let mut out = String::with_capacity(text.len() + text.lines().count() * (label.len() + 2));
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            out.push_str(line);
        } else if let Some(brace) = line.find('{') {
            out.push_str(&line[..=brace]);
            out.push_str(&label);
            out.push(',');
            out.push_str(&line[brace + 1..]);
        } else if let Some(space) = line.find(' ') {
            out.push_str(&line[..space]);
            out.push('{');
            out.push_str(&label);
            out.push('}');
            out.push_str(&line[space..]);
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    out
}

/// Prometheus sample value: integers render without a fraction.
fn num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::profile::{BatchProfile, LayerProfile, RoundProfile};
    use crate::obs::span::BatchTrace;
    use crate::util::json::JsonValue;

    fn traced_log() -> TraceLog {
        let layer = |index: usize, amc: u64| LayerProfile {
            index,
            batches: 2,
            inputs: 8,
            neurons: 4,
            rounds: vec![RoundProfile {
                config: (4, 2),
                rolls: 2,
                stream_cycles: 16,
                deferred_cycles: 2,
                switch_cycles: 1,
                active_mac_cycles: amc,
            }],
            compute_cycles: 18,
            switch_cycles: 1,
            active_mac_cycles: amc,
            cache_hit: Some(index == 0),
            ..Default::default()
        };
        TraceLog {
            tracks: vec!["dev".into()],
            wall: Vec::new(),
            batches: vec![BatchTrace {
                track: 0,
                batch: 0,
                requests: 2,
                wall_start_ns: 0,
                wall_dur_ns: 1,
                cycles: 40,
                time_ns: 80.0,
                energy_pj: 9.0,
                pe_dynamic_pj: 6.0,
                active_mac_cycles: 300,
                profile: BatchProfile { layers: vec![layer(0, 200), layer(1, 100)] },
            }],
            dropped_events: 0,
        }
    }

    #[test]
    fn aggregates_layers_and_splits_energy() {
        let layers = aggregate_layers(&traced_log());
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].index, 0);
        assert_eq!(layers[0].rolls, 2);
        assert_eq!(layers[0].deferred_cycles, 2);
        assert_eq!(layers[0].cache_hits, 1);
        assert_eq!(layers[1].cache_misses, 1);
        // 6 pJ split 200:100.
        assert!((layers[0].pe_dynamic_pj - 4.0).abs() < 1e-9);
        assert!((layers[1].pe_dynamic_pj - 2.0).abs() < 1e-9);
    }

    #[test]
    fn prometheus_text_is_well_formed() {
        let mut m = CoordinatorMetrics { requests: 5, ..Default::default() };
        m.record_latency(1_000);
        m.record_latency(2_000);
        let snap = MetricsSnapshot::new(m, Some(&traced_log()));
        let text = snap.prometheus_text();
        assert!(text.contains("npe_requests_total 5"));
        assert!(text.contains("# TYPE npe_latency_us histogram"));
        assert!(text.contains("npe_latency_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("npe_latency_us_count 2"));
        assert!(text.contains("npe_latency_us_sum 3"));
        assert!(text.contains("npe_layer_deferred_cycles_total{layer=\"0\"} 2"));
        // Every non-comment line is `name{labels}? value`.
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad sample value in: {line}");
            assert!(parts.next().is_some(), "no metric name in: {line}");
        }
    }

    #[test]
    fn tenant_label_lands_on_every_sample() {
        let mut m = CoordinatorMetrics { requests: 5, ..Default::default() };
        m.record_latency(1_000);
        let snap = MetricsSnapshot::new(m, Some(&traced_log())).with_tenant("mnist");
        let text = snap.prometheus_text();
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            assert!(line.contains("tenant=\"mnist\""), "unlabeled sample: {line}");
            // Still well-formed: `name{labels} value`.
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad sample value in: {line}");
        }
        // Bare names gain a label set; labeled names gain a first label.
        assert!(text.contains("npe_requests_total{tenant=\"mnist\"} 5"));
        assert!(text.contains("npe_latency_us_bucket{tenant=\"mnist\",le=\"+Inf\"} 1"));
        assert!(text.contains("npe_layer_rolls_total{tenant=\"mnist\",layer=\"0\"}"));
        // Headers stay untouched (one HELP/TYPE pair per metric).
        assert!(text.contains("# TYPE npe_requests_total counter"));
    }

    #[test]
    fn json_carries_the_tenant_field() {
        let snap = MetricsSnapshot::new(CoordinatorMetrics::default(), None);
        let v = JsonValue::parse(&snap.to_json()).expect("valid JSON");
        assert!(v.get("tenant").unwrap().as_str().is_none(), "standalone service: null");
        let labeled = MetricsSnapshot::new(CoordinatorMetrics::default(), None)
            .with_tenant("gcn");
        let v = JsonValue::parse(&labeled.to_json()).expect("valid JSON");
        assert_eq!(v.get("tenant").unwrap().as_str(), Some("gcn"));
    }

    #[test]
    fn json_snapshot_parses_back() {
        let m = CoordinatorMetrics { requests: 3, batches: 1, ..Default::default() };
        let snap = MetricsSnapshot::new(m, Some(&traced_log()));
        let v = JsonValue::parse(&snap.to_json()).expect("valid JSON");
        assert_eq!(v.get("requests").unwrap().as_u64(), Some(3));
        assert_eq!(v.get("layers").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("layers").unwrap().as_arr().unwrap()[0]
                .get("deferred_cycles")
                .unwrap()
                .as_u64(),
            Some(2)
        );
    }
}
