//! Metrics snapshot + Prometheus-style / JSON exposition.
//!
//! [`MetricsSnapshot`] joins the coordinator counters (with the shared
//! cache overlaid — the one consistent read the PR-6 cache-race fix
//! mandates) with per-layer attribution aggregated from the trace log.
//! Reachable from
//! [`NpeService::metrics_snapshot`](crate::serve::NpeService::metrics_snapshot)
//! and the CLI `obs` subcommand.

use super::slo::SloStatus;
use super::span::TraceLog;
use super::timeline::TimelineSnapshot;
use crate::coordinator::CoordinatorMetrics;
use crate::mapper::{CacheStats, Dataflow};
use crate::util::json::escape;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Aggregated attribution for one layer position across every traced
/// batch. Keyed by execution index within a batch — when one tracer is
/// shared across services serving *different* models, aggregate per
/// service instead (each service snapshots its own metrics).
#[derive(Debug, Default, Clone, PartialEq)]
pub struct LayerAgg {
    pub index: usize,
    /// Batches that executed this layer.
    pub batches: u64,
    /// Same-config rounds.
    pub rounds: u64,
    pub rolls: u64,
    pub stream_cycles: u64,
    /// The TCD deferred-completion tail, summed.
    pub deferred_cycles: u64,
    pub switch_cycles: u64,
    pub active_mac_cycles: u64,
    /// PE dynamic energy attributed to this layer (each batch's
    /// `pe_dynamic_pj` split proportionally to active MAC-cycles; the
    /// leak/memory components stay batch-level and are not re-split).
    pub pe_dynamic_pj: f64,
    pub cache_hits: u64,
    pub cache_misses: u64,
}

/// One consistent observability read: coordinator counters + per-layer
/// attribution + trace health.
#[derive(Debug, Clone, Default)]
pub struct MetricsSnapshot {
    /// Coordinator counters, cache stats already overlaid.
    pub metrics: CoordinatorMetrics,
    /// Per-layer attribution (empty when the service runs untraced).
    pub layers: Vec<LayerAgg>,
    /// Trace events lost to buffer bounds (0 in healthy runs).
    pub dropped_events: u64,
    /// Tenant this snapshot belongs to, when taken through a
    /// [`ModelRegistry`](crate::serve::ModelRegistry): the Prometheus
    /// exposition then carries `tenant="<name>"` on every sample and the
    /// JSON object a `tenant` field. `None` for a standalone service.
    pub tenant: Option<String>,
    /// SLO evaluation against this snapshot's latency histogram, when
    /// the service was built with an SLO
    /// ([`ServeBuilder::slo`](crate::serve::ServeBuilder::slo)).
    pub slo: Option<SloStatus>,
    /// Live-telemetry timeline, when the service was built with a
    /// sampler — its latest-sample gauges ride along in the Prometheus
    /// exposition.
    pub timeline: Option<TimelineSnapshot>,
}

/// Aggregate per-layer attribution out of a trace snapshot.
pub fn aggregate_layers(log: &TraceLog) -> Vec<LayerAgg> {
    let mut by_index: BTreeMap<usize, LayerAgg> = BTreeMap::new();
    for b in &log.batches {
        let total_amc: u64 = b.profile.layers.iter().map(|l| l.active_mac_cycles).sum();
        for layer in &b.profile.layers {
            let agg = by_index.entry(layer.index).or_insert_with(|| LayerAgg {
                index: layer.index,
                ..Default::default()
            });
            agg.batches += 1;
            agg.rounds += layer.rounds.len() as u64;
            agg.rolls += layer.rolls();
            agg.stream_cycles += layer.rounds.iter().map(|r| r.stream_cycles).sum::<u64>();
            agg.deferred_cycles += layer.deferred_cycles();
            agg.switch_cycles += layer.switch_cycles;
            agg.active_mac_cycles += layer.active_mac_cycles;
            if total_amc > 0 {
                agg.pe_dynamic_pj +=
                    b.pe_dynamic_pj * layer.active_mac_cycles as f64 / total_amc as f64;
            }
            match layer.cache_hit {
                Some(true) => agg.cache_hits += 1,
                Some(false) => agg.cache_misses += 1,
                None => {}
            }
        }
    }
    by_index.into_values().collect()
}

impl MetricsSnapshot {
    /// Build a snapshot from already-overlaid metrics and an optional
    /// trace log.
    pub fn new(metrics: CoordinatorMetrics, log: Option<&TraceLog>) -> Self {
        Self {
            layers: log.map(aggregate_layers).unwrap_or_default(),
            dropped_events: log.map(|l| l.dropped_events).unwrap_or(0),
            metrics,
            tenant: None,
            slo: None,
            timeline: None,
        }
    }

    /// Label this snapshot with a tenant name (builder form — the
    /// registry applies it when snapshotting per tenant).
    pub fn with_tenant(mut self, tenant: impl Into<String>) -> Self {
        self.tenant = Some(tenant.into());
        self
    }

    /// Attach an SLO evaluation (builder form — the service applies it
    /// when it has an [`SloTracker`](crate::obs::SloTracker)).
    pub fn with_slo(mut self, slo: SloStatus) -> Self {
        self.slo = Some(slo);
        self
    }

    /// Attach a telemetry timeline (builder form).
    pub fn with_timeline(mut self, timeline: TimelineSnapshot) -> Self {
        self.timeline = Some(timeline);
        self
    }

    /// Prometheus text exposition (classic format: `# TYPE` headers,
    /// counters/gauges, a classic histogram for wall latency, per-layer
    /// labeled attribution series).
    pub fn prometheus_text(&self) -> String {
        let m = &self.metrics;
        let mut out = String::new();
        let mut counter = |name: &str, help: &str, v: f64| {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            let _ = writeln!(out, "{name} {}", num(v));
        };
        counter("npe_requests_total", "Requests dispatched to a device.", m.requests as f64);
        counter("npe_rejected_requests_total", "Bad-shape refusals.", m.rejected_requests as f64);
        counter("npe_shed_requests_total", "Admission-control sheds.", m.shed_requests as f64);
        counter("npe_responses_dropped_total", "Dropped responses.", m.responses_dropped as f64);
        counter("npe_batches_total", "Batches executed.", m.batches as f64);
        counter("npe_padded_slots_total", "Padding rows added to batches.", m.padded_slots as f64);
        counter("npe_verified_batches_total", "PJRT-verified batches.", m.verified_batches as f64);
        counter("npe_verify_mismatches_total", "PJRT mismatches.", m.verify_mismatches as f64);
        counter("npe_sim_time_ns_total", "Simulated NPE time, ns.", m.sim_time_ns);
        counter("npe_sim_energy_pj_total", "Simulated NPE energy, pJ.", m.sim_energy_pj);
        counter("npe_cache_hits_total", "Schedule-cache hits.", m.cache_hits as f64);
        counter("npe_cache_misses_total", "Schedule-cache misses.", m.cache_misses as f64);
        counter("npe_cache_evictions_total", "Cache LRU evictions.", m.cache_evictions as f64);
        counter("npe_trace_dropped_events_total", "Trace events lost.", self.dropped_events as f64);

        // Per-dataflow schedule-cache lanes. Separate families from the
        // bare totals above, so no family ever mixes bare and labeled
        // samples (the exposition format forbids that).
        let lane_families: [(&str, &str, fn(CacheStats) -> u64); 3] = [
            ("npe_cache_lane_hits_total", "Schedule-cache hits per dataflow lane.", |s| s.hits),
            (
                "npe_cache_lane_misses_total",
                "Schedule-cache misses per dataflow lane.",
                |s| s.misses,
            ),
            (
                "npe_cache_lane_evictions_total",
                "Cache LRU evictions per dataflow lane.",
                |s| s.evictions,
            ),
        ];
        for (name, help, get) in lane_families {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            for d in Dataflow::ALL {
                let _ = writeln!(
                    out,
                    "{name}{{dataflow=\"{}\"}} {}",
                    d.name(),
                    get(m.cache_lane(d))
                );
            }
        }

        let _ = writeln!(out, "# HELP npe_queue_peak Deepest the work queue ever got.");
        let _ = writeln!(out, "# TYPE npe_queue_peak gauge");
        let _ = writeln!(out, "npe_queue_peak {}", m.queue_peak);

        // Wall latency as a classic histogram, in µs. The bucket ladder
        // is the histogram's *stable* power-of-two set: every scrape
        // emits the same `le` edges regardless of the data, so PromQL
        // `histogram_quantile` rate windows never see the bucket set
        // shift under them (the non-empty-only exposition used to).
        let _ = writeln!(out, "# HELP npe_latency_us Wall latency submit to response, us.");
        let _ = writeln!(out, "# TYPE npe_latency_us histogram");
        for (upper_ns, cum) in m.latencies.stable_cumulative_buckets() {
            let _ = writeln!(
                out,
                "npe_latency_us_bucket{{le=\"{}\"}} {cum}",
                num(upper_ns as f64 / 1e3)
            );
        }
        let _ = writeln!(out, "npe_latency_us_bucket{{le=\"+Inf\"}} {}", m.latencies.count());
        let _ = writeln!(out, "npe_latency_us_sum {}", num(m.latencies.sum() as f64 / 1e3));
        let _ = writeln!(out, "npe_latency_us_count {}", m.latencies.count());

        // Per-device lanes.
        let _ = writeln!(out, "# HELP npe_device_requests_total Requests per device lane.");
        let _ = writeln!(out, "# TYPE npe_device_requests_total counter");
        for (i, d) in m.devices.iter().enumerate() {
            let _ = writeln!(
                out,
                "npe_device_requests_total{{device=\"{i}\",geometry=\"{}\"}} {}",
                escape(&d.geometry),
                d.requests
            );
        }

        // Per-layer attribution.
        let series: [(&str, &str, fn(&LayerAgg) -> f64); 6] = [
            ("npe_layer_rolls_total", "Rolls executed per layer.", |l| l.rolls as f64),
            ("npe_layer_rounds_total", "Same-config rounds per layer.", |l| l.rounds as f64),
            ("npe_layer_stream_cycles_total", "Streaming cycles.", |l| l.stream_cycles as f64),
            ("npe_layer_deferred_cycles_total", "TCD tail cycles.", |l| l.deferred_cycles as f64),
            ("npe_layer_switch_cycles_total", "Reconfig dead cycles.", |l| l.switch_cycles as f64),
            ("npe_layer_pe_dynamic_pj_total", "PE dynamic energy, pJ.", |l| l.pe_dynamic_pj),
        ];
        for (name, help, get) in series {
            let _ = writeln!(out, "# HELP {name} {help}");
            let _ = writeln!(out, "# TYPE {name} counter");
            for l in &self.layers {
                let _ = writeln!(out, "{name}{{layer=\"{}\"}} {}", l.index, num(get(l)));
            }
        }

        // SLO surfaces (absent unless the service has an objective).
        if let Some(slo) = &self.slo {
            let _ = writeln!(out, "# HELP npe_slo_objective_us Latency objective, us.");
            let _ = writeln!(out, "# TYPE npe_slo_objective_us gauge");
            let _ = writeln!(out, "npe_slo_objective_us {}", slo.objective_us);
            let _ = writeln!(out, "# HELP npe_slo_target Required good fraction.");
            let _ = writeln!(out, "# TYPE npe_slo_target gauge");
            let _ = writeln!(out, "npe_slo_target {}", slo.target);
            let _ = writeln!(out, "# HELP npe_slo_good_total Requests inside the objective.");
            let _ = writeln!(out, "# TYPE npe_slo_good_total counter");
            let _ = writeln!(out, "npe_slo_good_total {}", slo.good);
            let _ = writeln!(out, "# HELP npe_slo_bad_total Requests outside the objective.");
            let _ = writeln!(out, "# TYPE npe_slo_bad_total counter");
            let _ = writeln!(out, "npe_slo_bad_total {}", slo.bad);
            let _ = writeln!(out, "# HELP npe_slo_compliance Observed good fraction.");
            let _ = writeln!(out, "# TYPE npe_slo_compliance gauge");
            let _ = writeln!(out, "npe_slo_compliance {:.6}", slo.compliance);
            let _ = writeln!(out, "# HELP npe_slo_burn_rate Error-budget burn rate.");
            let _ = writeln!(out, "# TYPE npe_slo_burn_rate gauge");
            if slo.burn_rate.is_finite() {
                let _ = writeln!(out, "npe_slo_burn_rate {:.6}", slo.burn_rate);
            } else {
                let _ = writeln!(out, "npe_slo_burn_rate +Inf");
            }
        }

        // Live-telemetry gauges from the latest sampler tick.
        if let Some(tl) = &self.timeline {
            out.push_str(&tl.prometheus_gauges());
        }

        match &self.tenant {
            None => out,
            Some(tenant) => inject_tenant_label(&out, tenant),
        }
    }

    /// The snapshot as one JSON object (hand-rolled, same idiom as the
    /// bench writers).
    pub fn to_json(&self) -> String {
        let m = &self.metrics;
        let mut layers = String::new();
        for (i, l) in self.layers.iter().enumerate() {
            if i > 0 {
                layers.push(',');
            }
            let _ = write!(
                layers,
                "{{\"index\":{},\"batches\":{},\"rounds\":{},\"rolls\":{},\
                 \"stream_cycles\":{},\"deferred_cycles\":{},\"switch_cycles\":{},\
                 \"active_mac_cycles\":{},\"pe_dynamic_pj\":{:.3},\
                 \"cache_hits\":{},\"cache_misses\":{}}}",
                l.index,
                l.batches,
                l.rounds,
                l.rolls,
                l.stream_cycles,
                l.deferred_cycles,
                l.switch_cycles,
                l.active_mac_cycles,
                l.pe_dynamic_pj,
                l.cache_hits,
                l.cache_misses,
            );
        }
        let mut devices = String::new();
        for (i, d) in m.devices.iter().enumerate() {
            if i > 0 {
                devices.push(',');
            }
            let _ = write!(
                devices,
                "{{\"device\":{i},\"geometry\":\"{}\",\"batches\":{},\"requests\":{},\
                 \"sim_busy_ns\":{:.3}}}",
                escape(&d.geometry),
                d.batches,
                d.requests,
                d.sim_busy_ns,
            );
        }
        let mut cache_lanes = String::new();
        for (i, d) in Dataflow::ALL.iter().enumerate() {
            if i > 0 {
                cache_lanes.push(',');
            }
            let l = m.cache_lane(*d);
            let _ = write!(
                cache_lanes,
                "{{\"dataflow\":\"{}\",\"hits\":{},\"misses\":{},\"evictions\":{}}}",
                d.name(),
                l.hits,
                l.misses,
                l.evictions,
            );
        }
        let tenant = match &self.tenant {
            Some(t) => format!("\"{}\"", escape(t)),
            None => "null".to_string(),
        };
        // JSON has no Infinity literal: a non-finite burn rate (perfect
        // target, any miss) serializes as null.
        let slo = match &self.slo {
            Some(s) => format!(
                "{{\"objective_us\":{},\"target\":{},\"good\":{},\"bad\":{},\
                 \"compliance\":{:.6},\"burn_rate\":{}}}",
                s.objective_us,
                s.target,
                s.good,
                s.bad,
                s.compliance,
                if s.burn_rate.is_finite() {
                    format!("{:.6}", s.burn_rate)
                } else {
                    "null".to_string()
                },
            ),
            None => "null".to_string(),
        };
        format!(
            "{{\"tenant\":{tenant},\"slo\":{slo},\
             \"requests\":{},\"rejected_requests\":{},\"shed_requests\":{},\
             \"responses_dropped\":{},\"batches\":{},\"padded_slots\":{},\
             \"verified_batches\":{},\"verify_mismatches\":{},\
             \"sim_time_ns\":{:.3},\"sim_energy_pj\":{:.3},\
             \"cache_hits\":{},\"cache_misses\":{},\"cache_evictions\":{},\
             \"cache_lanes\":[{cache_lanes}],\
             \"queue_peak\":{},\"latencies_recorded\":{},\
             \"wall_p50_us\":{:.3},\"wall_p95_us\":{:.3},\"wall_p99_us\":{:.3},\
             \"dropped_events\":{},\"devices\":[{devices}],\"layers\":[{layers}]}}\n",
            m.requests,
            m.rejected_requests,
            m.shed_requests,
            m.responses_dropped,
            m.batches,
            m.padded_slots,
            m.verified_batches,
            m.verify_mismatches,
            m.sim_time_ns,
            m.sim_energy_pj,
            m.cache_hits,
            m.cache_misses,
            m.cache_evictions,
            m.queue_peak,
            m.latencies_recorded,
            m.p50_us(),
            m.p95_us(),
            m.p99_us(),
            self.dropped_events,
        )
    }
}

/// Merge several Prometheus expositions (e.g. one per tenant) into one
/// document with exactly one `# HELP`/`# TYPE` header per metric
/// family: samples are regrouped under the family's first-seen header,
/// in first-appearance order. Naive concatenation repeats headers per
/// tenant, which the exposition format forbids ("Only one TYPE line may
/// exist for a given metric name").
///
/// Histogram child samples (`_bucket`/`_sum`/`_count`) fold into their
/// parent family when that family was declared by a `# TYPE` line
/// earlier in the same input — which every exposition this repo writes
/// does.
pub fn merge_expositions<'a>(texts: impl IntoIterator<Item = &'a str>) -> String {
    #[derive(Default)]
    struct Family {
        help: Option<String>,
        kind: Option<String>,
        samples: Vec<String>,
    }
    let mut order: Vec<String> = Vec::new();
    let mut families: BTreeMap<String, Family> = BTreeMap::new();
    let mut family_entry = |order: &mut Vec<String>,
                            families: &mut BTreeMap<String, Family>,
                            name: &str|
     -> String {
        if !families.contains_key(name) {
            order.push(name.to_string());
            families.insert(name.to_string(), Family::default());
        }
        name.to_string()
    };
    for text in texts {
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix("# HELP ") {
                let name = rest.split(' ').next().unwrap_or(rest);
                let key = family_entry(&mut order, &mut families, name);
                if let Some(f) = families.get_mut(&key) {
                    f.help.get_or_insert_with(|| line.to_string());
                }
            } else if let Some(rest) = line.strip_prefix("# TYPE ") {
                let name = rest.split(' ').next().unwrap_or(rest);
                let key = family_entry(&mut order, &mut families, name);
                if let Some(f) = families.get_mut(&key) {
                    f.kind.get_or_insert_with(|| line.to_string());
                }
            } else if line.starts_with('#') {
                // Free-form comments don't survive a merge: they have
                // no family to travel with.
            } else {
                let raw = line
                    .find(|c| c == '{' || c == ' ')
                    .map_or(line, |cut| &line[..cut]);
                let name = ["_bucket", "_sum", "_count"]
                    .iter()
                    .find_map(|suf| {
                        raw.strip_suffix(suf).filter(|base| families.contains_key(*base))
                    })
                    .unwrap_or(raw);
                let key = family_entry(&mut order, &mut families, name);
                if let Some(f) = families.get_mut(&key) {
                    f.samples.push(line.to_string());
                }
            }
        }
    }
    let mut out = String::new();
    for name in &order {
        if let Some(f) = families.get(name) {
            if let Some(h) = &f.help {
                out.push_str(h);
                out.push('\n');
            }
            if let Some(k) = &f.kind {
                out.push_str(k);
                out.push('\n');
            }
            for s in &f.samples {
                out.push_str(s);
                out.push('\n');
            }
        }
    }
    out
}

/// Inject `tenant="<name>"` into every sample line of a Prometheus
/// exposition: bare names gain a label set, labeled names gain a first
/// label. Comment lines (`# HELP` / `# TYPE`) pass through untouched.
fn inject_tenant_label(text: &str, tenant: &str) -> String {
    let label = format!("tenant=\"{}\"", escape(tenant));
    let mut out = String::with_capacity(text.len() + text.lines().count() * (label.len() + 2));
    for line in text.lines() {
        if line.starts_with('#') || line.is_empty() {
            out.push_str(line);
        } else if let Some(brace) = line.find('{') {
            out.push_str(&line[..=brace]);
            out.push_str(&label);
            out.push(',');
            out.push_str(&line[brace + 1..]);
        } else if let Some(space) = line.find(' ') {
            out.push_str(&line[..space]);
            out.push('{');
            out.push_str(&label);
            out.push('}');
            out.push_str(&line[space..]);
        } else {
            out.push_str(line);
        }
        out.push('\n');
    }
    out
}

/// Prometheus sample value: integers render without a fraction.
fn num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.0e15 {
        format!("{}", v as i64)
    } else {
        format!("{v:.3}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::profile::{BatchProfile, LayerProfile, RoundProfile};
    use crate::obs::span::BatchTrace;
    use crate::util::json::JsonValue;

    fn traced_log() -> TraceLog {
        let layer = |index: usize, amc: u64| LayerProfile {
            index,
            batches: 2,
            inputs: 8,
            neurons: 4,
            rounds: vec![RoundProfile {
                config: (4, 2),
                rolls: 2,
                stream_cycles: 16,
                deferred_cycles: 2,
                switch_cycles: 1,
                active_mac_cycles: amc,
            }],
            compute_cycles: 18,
            switch_cycles: 1,
            active_mac_cycles: amc,
            cache_hit: Some(index == 0),
            ..Default::default()
        };
        TraceLog {
            tracks: vec!["dev".into()],
            wall: Vec::new(),
            batches: vec![BatchTrace {
                track: 0,
                batch: 0,
                requests: 2,
                wall_start_ns: 0,
                wall_dur_ns: 1,
                cycles: 40,
                time_ns: 80.0,
                energy_pj: 9.0,
                pe_dynamic_pj: 6.0,
                active_mac_cycles: 300,
                profile: BatchProfile { layers: vec![layer(0, 200), layer(1, 100)] },
            }],
            dropped_events: 0,
        }
    }

    #[test]
    fn aggregates_layers_and_splits_energy() {
        let layers = aggregate_layers(&traced_log());
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].index, 0);
        assert_eq!(layers[0].rolls, 2);
        assert_eq!(layers[0].deferred_cycles, 2);
        assert_eq!(layers[0].cache_hits, 1);
        assert_eq!(layers[1].cache_misses, 1);
        // 6 pJ split 200:100.
        assert!((layers[0].pe_dynamic_pj - 4.0).abs() < 1e-9);
        assert!((layers[1].pe_dynamic_pj - 2.0).abs() < 1e-9);
    }

    #[test]
    fn prometheus_text_is_well_formed() {
        let mut m = CoordinatorMetrics { requests: 5, ..Default::default() };
        m.record_latency(1_000);
        m.record_latency(2_000);
        m.set_cache_lanes([
            CacheStats { hits: 3, misses: 1, evictions: 0 },
            CacheStats::default(),
            CacheStats { hits: 0, misses: 2, evictions: 1 },
            CacheStats::default(),
        ]);
        let snap = MetricsSnapshot::new(m, Some(&traced_log()));
        let text = snap.prometheus_text();
        assert!(text.contains("npe_requests_total 5"));
        // Per-dataflow lane families: labeled series summing to the bare
        // totals, every lane present even when idle.
        assert!(text.contains("npe_cache_hits_total 3"));
        assert!(text.contains("npe_cache_lane_hits_total{dataflow=\"os\"} 3"));
        assert!(text.contains("npe_cache_lane_misses_total{dataflow=\"nlr\"} 2"));
        assert!(text.contains("npe_cache_lane_evictions_total{dataflow=\"nlr\"} 1"));
        assert!(text.contains("npe_cache_lane_hits_total{dataflow=\"ws\"} 0"));
        assert!(text.contains("npe_cache_lane_hits_total{dataflow=\"rna\"} 0"));
        assert!(text.contains("# TYPE npe_latency_us histogram"));
        assert!(text.contains("npe_latency_us_bucket{le=\"+Inf\"} 2"));
        assert!(text.contains("npe_latency_us_count 2"));
        assert!(text.contains("npe_latency_us_sum 3"));
        assert!(text.contains("npe_layer_deferred_cycles_total{layer=\"0\"} 2"));
        // Every non-comment line is `name{labels}? value`.
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad sample value in: {line}");
            assert!(parts.next().is_some(), "no metric name in: {line}");
        }
    }

    #[test]
    fn tenant_label_lands_on_every_sample() {
        let mut m = CoordinatorMetrics { requests: 5, ..Default::default() };
        m.record_latency(1_000);
        let snap = MetricsSnapshot::new(m, Some(&traced_log())).with_tenant("mnist");
        let text = snap.prometheus_text();
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            assert!(line.contains("tenant=\"mnist\""), "unlabeled sample: {line}");
            // Still well-formed: `name{labels} value`.
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "bad sample value in: {line}");
        }
        // Bare names gain a label set; labeled names gain a first label.
        assert!(text.contains("npe_requests_total{tenant=\"mnist\"} 5"));
        assert!(text.contains("npe_latency_us_bucket{tenant=\"mnist\",le=\"+Inf\"} 1"));
        assert!(text.contains("npe_layer_rolls_total{tenant=\"mnist\",layer=\"0\"}"));
        // Headers stay untouched (one HELP/TYPE pair per metric).
        assert!(text.contains("# TYPE npe_requests_total counter"));
    }

    /// A sample line is well-formed when it is `name value` or
    /// `name{k="v",...} value` with exactly one balanced label set.
    fn assert_well_formed_sample(line: &str) {
        let opens = line.matches('{').count();
        let closes = line.matches('}').count();
        assert!(opens == closes && opens <= 1, "malformed label set: {line}");
        let (head, value) = line.rsplit_once(' ').expect("name and value");
        assert!(value.parse::<f64>().is_ok(), "bad sample value in: {line}");
        if let Some((name, labels)) = head.split_once('{') {
            assert!(!name.is_empty() && !name.contains(' '), "bad name in: {line}");
            let labels = labels.strip_suffix('}').expect("closed label set");
            for pair in labels.split(',') {
                let (k, v) = pair.split_once('=').expect("k=v label");
                assert!(!k.is_empty() && !k.contains('"'), "bad label key in: {line}");
                assert!(
                    v.len() >= 2 && v.starts_with('"') && v.ends_with('"'),
                    "unquoted label value in: {line}"
                );
            }
        }
    }

    #[test]
    fn tenant_label_merges_into_already_labeled_samples() {
        // Histogram le= lines AND device= lanes both already carry
        // labels; the tenant must merge in as a first label, leaving
        // exactly one well-formed label set per line.
        let mut m = CoordinatorMetrics { requests: 5, ..Default::default() };
        m.record_latency(1_000);
        m.record_latency(50_000);
        m.devices.push(crate::coordinator::DeviceMetrics {
            geometry: "16x8".into(),
            batches: 2,
            requests: 5,
            sim_busy_ns: 100.0,
        });
        let snap = MetricsSnapshot::new(m, Some(&traced_log())).with_tenant("iris");
        let text = snap.prometheus_text();
        for line in text.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            assert!(line.contains("tenant=\"iris\""), "unlabeled sample: {line}");
            assert_well_formed_sample(line);
        }
        assert!(text.contains(
            "npe_device_requests_total{tenant=\"iris\",device=\"0\",geometry=\"16x8\"} 5"
        ));
        assert!(
            text.contains("npe_cache_lane_hits_total{tenant=\"iris\",dataflow=\"os\"}"),
            "tenant label merges into dataflow-labeled lane samples"
        );
        assert!(text.contains("npe_latency_us_bucket{tenant=\"iris\",le=\"+Inf\"} 2"));
        // Tenant lands first even on the stable-ladder bucket lines.
        for line in text.lines().filter(|l| l.starts_with("npe_latency_us_bucket")) {
            assert!(line.starts_with("npe_latency_us_bucket{tenant=\"iris\","), "{line}");
        }
    }

    #[test]
    fn bucket_ladder_is_identical_across_different_data() {
        let le_set = |m: CoordinatorMetrics| -> Vec<String> {
            MetricsSnapshot::new(m, None)
                .prometheus_text()
                .lines()
                .filter(|l| l.starts_with("npe_latency_us_bucket{"))
                .map(|l| l.split('"').nth(1).unwrap_or("").to_string())
                .collect()
        };
        let empty = le_set(CoordinatorMetrics::default());
        let mut a = CoordinatorMetrics::default();
        a.record_latency(30);
        let mut b = CoordinatorMetrics::default();
        for v in [1_000u64, 77_777, 1 << 33] {
            b.record_latency(v);
        }
        // Satellite fix: the le set used to be "non-empty buckets only",
        // so it changed between scrapes as new buckets filled.
        assert_eq!(le_set(a), empty);
        assert_eq!(le_set(b), empty);
        assert_eq!(empty.len(), 65, "64 power-of-two edges + +Inf");
    }

    #[test]
    fn merge_expositions_keeps_one_type_header_per_family() {
        let mk = |tenant: &str, requests: u64| {
            let mut m = CoordinatorMetrics { requests, ..Default::default() };
            m.record_latency(1_000);
            MetricsSnapshot::new(m, Some(&traced_log())).with_tenant(tenant).prometheus_text()
        };
        let merged = merge_expositions([mk("iris", 5).as_str(), mk("lenet", 7).as_str()]);
        // Exactly one # TYPE (and one # HELP) line per metric family.
        let mut seen = std::collections::BTreeMap::new();
        for line in merged.lines() {
            if let Some(rest) = line.strip_prefix("# TYPE ") {
                let fam = rest.split(' ').next().unwrap_or("");
                *seen.entry(fam.to_string()).or_insert(0u32) += 1;
            }
        }
        assert!(!seen.is_empty());
        for (fam, n) in &seen {
            assert_eq!(*n, 1, "family {fam} declared {n} times");
        }
        // Both tenants' samples survive, grouped after their header.
        assert!(merged.contains("npe_requests_total{tenant=\"iris\"} 5"));
        assert!(merged.contains("npe_requests_total{tenant=\"lenet\"} 7"));
        // Histogram children fold under the parent family: the
        // histogram TYPE appears once, and every bucket line of both
        // tenants sits below it before the next # TYPE.
        let hist_at = merged.find("# TYPE npe_latency_us histogram").expect("histogram header");
        let after = &merged[hist_at..];
        let section_end = after[1..].find("# TYPE").map(|i| i + 1).unwrap_or(after.len());
        let section = &after[..section_end];
        assert!(section.contains("tenant=\"iris\",le=\"+Inf\""));
        assert!(section.contains("tenant=\"lenet\",le=\"+Inf\""));
        // Every sample line stays well-formed after the merge.
        for line in merged.lines().filter(|l| !l.starts_with('#') && !l.is_empty()) {
            assert_well_formed_sample(line);
        }
    }

    #[test]
    fn slo_and_timeline_surface_in_prometheus() {
        use crate::obs::slo::{SloConfig, SloTracker};
        let mut m = CoordinatorMetrics::default();
        for _ in 0..9 {
            m.record_latency(10_000);
        }
        m.record_latency(1_024_000);
        let slo = SloTracker::new(SloConfig::new(16, 0.95)).evaluate(&m.latencies);
        let snap = MetricsSnapshot::new(m, None).with_slo(slo);
        let text = snap.prometheus_text();
        assert!(text.contains("npe_slo_objective_us 16"));
        assert!(text.contains("npe_slo_good_total 9"));
        assert!(text.contains("npe_slo_bad_total 1"));
        assert!(text.contains("npe_slo_compliance 0.9"));
        assert!(text.contains("# TYPE npe_slo_burn_rate gauge"));
        let v = JsonValue::parse(&snap.to_json()).expect("valid JSON with slo");
        assert_eq!(v.get("slo").unwrap().get("good").unwrap().as_u64(), Some(9));
        // Infinite burn serializes as +Inf (Prometheus) / null (JSON).
        let mut m = CoordinatorMetrics::default();
        m.record_latency(1_024_000);
        let slo = SloTracker::new(SloConfig::new(16, 1.0)).evaluate(&m.latencies);
        let snap = MetricsSnapshot::new(m, None).with_slo(slo);
        assert!(snap.prometheus_text().contains("npe_slo_burn_rate +Inf"));
        let v = JsonValue::parse(&snap.to_json()).expect("valid JSON");
        assert!(v.get("slo").unwrap().get("burn_rate").unwrap().as_f64().is_none());
    }

    #[test]
    fn json_carries_the_tenant_field() {
        let snap = MetricsSnapshot::new(CoordinatorMetrics::default(), None);
        let v = JsonValue::parse(&snap.to_json()).expect("valid JSON");
        assert!(v.get("tenant").unwrap().as_str().is_none(), "standalone service: null");
        let labeled = MetricsSnapshot::new(CoordinatorMetrics::default(), None)
            .with_tenant("gcn");
        let v = JsonValue::parse(&labeled.to_json()).expect("valid JSON");
        assert_eq!(v.get("tenant").unwrap().as_str(), Some("gcn"));
    }

    #[test]
    fn json_snapshot_parses_back() {
        let mut m = CoordinatorMetrics { requests: 3, batches: 1, ..Default::default() };
        m.set_cache_lanes([
            CacheStats { hits: 7, misses: 2, evictions: 0 },
            CacheStats::default(),
            CacheStats::default(),
            CacheStats { hits: 0, misses: 1, evictions: 0 },
        ]);
        let snap = MetricsSnapshot::new(m, Some(&traced_log()));
        let v = JsonValue::parse(&snap.to_json()).expect("valid JSON");
        assert_eq!(v.get("requests").unwrap().as_u64(), Some(3));
        let lanes = v.get("cache_lanes").unwrap().as_arr().unwrap();
        assert_eq!(lanes.len(), 4, "one entry per dataflow lane");
        assert_eq!(lanes[0].get("dataflow").unwrap().as_str(), Some("os"));
        assert_eq!(lanes[0].get("hits").unwrap().as_u64(), Some(7));
        assert_eq!(lanes[3].get("dataflow").unwrap().as_str(), Some("rna"));
        assert_eq!(lanes[3].get("misses").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("layers").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(
            v.get("layers").unwrap().as_arr().unwrap()[0]
                .get("deferred_cycles")
                .unwrap()
                .as_u64(),
            Some(2)
        );
    }
}
