//! Bench E11 — the Fig.-7 memory model: access-count reductions per
//! configuration and traffic-accounting throughput, plus the RLC codec.
//!
//! Run: `cargo bench --bench memory_bench`

use tcd_npe::bench::BenchTimer;
use tcd_npe::mapper::{MapperTree, NpeGeometry};
use tcd_npe::memory::rlc::RlcCodec;
use tcd_npe::memory::{FmArrangement, NpeMemorySystem, WMemArrangement};
use tcd_npe::model::{benchmarks, QuantizedMlp};
use tcd_npe::util::SplitMix64;

fn main() {
    println!("=== Fig. 7 worked example (NPE(2,64), Γ(2,200,100)) ===");
    let w = WMemArrangement { row_words: 128, n: 64, inputs: 200, neurons: 100 };
    let f = FmArrangement { row_words: 64, batches: 2, inputs: 200 };
    println!(
        "W-Mem: {} rows/group x {} groups, access reduction {:.0}x (paper: 100 x 2, 2x)",
        w.rows_per_group(),
        w.groups(),
        w.access_reduction()
    );
    println!(
        "FM-Mem: {} rows/batch, access reduction {:.0}x (paper: 7, 32x)\n",
        f.rows_per_batch(),
        f.access_reduction()
    );

    println!("=== traffic accounting throughput ===");
    for bench in benchmarks() {
        let mlp = QuantizedMlp::synthesize(bench.topology.clone(), 1);
        let inputs = mlp.synth_inputs(10, 2);
        let mut mapper = MapperTree::new(NpeGeometry::PAPER);
        let schedule = mapper.schedule_model(&bench.topology, 10);
        let mut t = BenchTimer::new(format!("traffic/{}", bench.dataset.replace(' ', "-")));
        t.run(1, 5, || {
            let mut mem = NpeMemorySystem::new();
            mem.account_schedule(&schedule, &mlp, &inputs)
        });
        println!("{}", t.report());
    }

    println!("\n=== RLC codec ===");
    let mut rng = SplitMix64::new(3);
    for (label, zero_pct) in [("dense", 0u64), ("relu-like-60", 60), ("sparse-90", 90)] {
        let data: Vec<i16> = (0..65536)
            .map(|_| {
                if rng.next_u64() % 100 < zero_pct {
                    0
                } else {
                    rng.next_i16()
                }
            })
            .collect();
        let bits = RlcCodec::encoded_bits(&data);
        let mut t = BenchTimer::new(format!("rlc/encode+decode/{label}"));
        t.run(1, 5, || RlcCodec::decode(&RlcCodec::encode(&data)).len());
        println!(
            "{}   (compression: {:.2}x)",
            t.report(),
            (data.len() as f64 * 16.0) / bits as f64
        );
    }
}
