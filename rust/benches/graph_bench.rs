//! Bench E8 — the graph compiler: scheduled rounds, cycles and energy of
//! the DAG zoo under fused (passes + sibling-shared lowering) vs unfused
//! (per-node) lowering.
//!
//! Run: `cargo bench --bench graph_bench`
//!
//! Emits `BENCH_graph.json` in the working directory so CI can archive
//! the trajectory (round savings per DAG entry) across PRs.

use tcd_npe::bench::{graph_json, graph_rows, render_graph_table, GRAPH_BATCHES};

fn main() {
    println!("=== graph compiler: fused vs unfused lowering, DAG zoo ===");
    let rows = graph_rows(GRAPH_BATCHES);
    println!("{}", render_graph_table(&rows, GRAPH_BATCHES));

    for r in &rows {
        println!(
            "{:<14} rounds {:>4} fused / {:>4} unfused ({:.0}% saved)",
            r.network,
            r.fused_rounds,
            r.unfused_rounds,
            r.round_saving() * 100.0
        );
    }

    let json = graph_json(&rows, GRAPH_BATCHES);
    match std::fs::write("BENCH_graph.json", &json) {
        Ok(()) => println!("\nwrote BENCH_graph.json"),
        Err(e) => eprintln!("\ncould not write BENCH_graph.json: {e}"),
    }
}
