//! Bench E7 — fleet serving: simulated throughput and wall-latency
//! percentiles vs device count (1/2/4/8) under the seeded Poisson load,
//! the cached-vs-cold Algorithm-1 microbenchmark, the admission-policy
//! sweep (Block vs Reject at 2× saturation), the two-tenant contention
//! sweep on a shared registry pool, and the fixed-vs-elastic load-step
//! sweep.
//!
//! Run: `cargo bench --bench fleet_bench`
//!
//! Emits `BENCH_fleet.json` in the working directory so CI can archive
//! the trajectory (throughput/p99/shed rate vs device count, policy,
//! tenant and elastic scenario) across PRs.

#![deny(deprecated)]

use tcd_npe::bench::{
    admission_rows, elastic_rows, fleet_json, fleet_rows, mapper_cache_bench,
    render_admission_table, render_elastic_table, render_fleet_table, render_tenant_table,
    tenant_rows,
};
use tcd_npe::fleet::LoadGenConfig;

fn main() {
    let load = LoadGenConfig::default();

    println!("=== fleet serving: throughput & latency vs device count ===");
    let rows = fleet_rows(&load);
    println!("{}", render_fleet_table(&rows, &load));

    println!("=== admission policies at 2x saturation (1 device) ===");
    let admission = admission_rows(&load);
    println!("{}", render_admission_table(&admission));

    println!("=== two tenants on one shared registry pool ===");
    let tenants = tenant_rows(&load);
    println!("{}", render_tenant_table(&tenants));

    println!("=== elastic pool vs fixed-min baseline under a load step ===");
    let elastic = elastic_rows(&load);
    println!("{}", render_elastic_table(&elastic));

    println!("=== Algorithm-1 cold vs schedule cache (Table-IV Γ set, B=8) ===");
    let mapper = mapper_cache_bench(200);
    println!(
        "{} shapes: cold {:.1} us/iter, cached {:.1} us/iter ({:.0}x)",
        mapper.shapes,
        mapper.cold_us,
        mapper.cached_us,
        mapper.speedup()
    );

    let json = fleet_json(&rows, &admission, &tenants, &elastic, &mapper, &load);
    match std::fs::write("BENCH_fleet.json", &json) {
        Ok(()) => println!("\nwrote BENCH_fleet.json"),
        Err(e) => eprintln!("\ncould not write BENCH_fleet.json: {e}"),
    }
}
