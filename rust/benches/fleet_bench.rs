//! Bench E7 — fleet serving: simulated throughput and wall-latency
//! percentiles vs device count (1/2/4/8) under the seeded Poisson load,
//! plus the cached-vs-cold Algorithm-1 microbenchmark.
//!
//! Run: `cargo bench --bench fleet_bench`
//!
//! Emits `BENCH_fleet.json` in the working directory so CI can archive
//! the trajectory (throughput/p99 vs device count) across PRs.

use tcd_npe::bench::{fleet_json, fleet_rows, mapper_cache_bench, render_fleet_table};
use tcd_npe::fleet::LoadGenConfig;

fn main() {
    let load = LoadGenConfig::default();

    println!("=== fleet serving: throughput & latency vs device count ===");
    let rows = fleet_rows(&load);
    println!("{}", render_fleet_table(&rows, &load));

    println!("=== Algorithm-1 cold vs schedule cache (Table-IV Γ set, B=8) ===");
    let mapper = mapper_cache_bench(200);
    println!(
        "{} shapes: cold {:.1} us/iter, cached {:.1} us/iter ({:.0}x)",
        mapper.shapes,
        mapper.cold_us,
        mapper.cached_us,
        mapper.speedup()
    );

    let json = fleet_json(&rows, &mapper, &load);
    match std::fs::write("BENCH_fleet.json", &json) {
        Ok(()) => println!("\nwrote BENCH_fleet.json"),
        Err(e) => eprintln!("\ncould not write BENCH_fleet.json: {e}"),
    }
}
