//! Bench E1 — regenerates Table I (MAC PPA) and times the 20K-cycle
//! activity characterization of each design point.
//!
//! Run: `cargo bench --bench table1_mac_ppa`

use tcd_npe::bench::{render_table1, table1_rows, BenchTimer};
use tcd_npe::tcdmac::{measure_activity, MacKind};

fn main() {
    println!("=== Table I: PPA of conventional MACs vs TCD-MAC ===\n");
    println!("{}", render_table1(&table1_rows()));

    println!("characterization cost (20K-cycle activity sim per design):");
    for kind in MacKind::table1_order() {
        let mut t = BenchTimer::new(format!("activity/{}", kind.name()));
        t.run(1, 5, || measure_activity(kind, 20_000, 1));
        println!("{}", t.report());
    }
}
