//! Bench E2 — regenerates Table II (stream throughput/energy improvement)
//! and additionally *executes* streams of each length on the functional
//! MAC models to time the simulation substrate itself.
//!
//! Run: `cargo bench --bench table2_stream`

use tcd_npe::bench::{render_table2, table2_rows, BenchTimer, STREAM_SIZES};
use tcd_npe::tcdmac::MacKind;
use tcd_npe::util::SplitMix64;

fn main() {
    println!("=== Table II: TCD-MAC improvement vs stream length ===\n");
    println!("{}", render_table2(&table2_rows()));
    println!(
        "(column labels corrected vs the paper — its throughput/energy headers\n\
         are swapped; derivation pinned in bench::table2 tests)\n"
    );

    println!("functional-model stream execution cost:");
    for kind in [MacKind::Tcd, tcd_npe::dataflow::best_conventional()] {
        for n in STREAM_SIZES {
            let mut t = BenchTimer::new(format!("stream/{}/{n}", kind.name()));
            t.run(1, 5, || {
                let mut mac = kind.build();
                let mut rng = SplitMix64::new(7);
                for _ in 0..n {
                    mac.step(rng.next_i16(), rng.next_i16());
                }
                mac.finalize()
            });
            println!("{}", t.report());
        }
    }
}
