//! Bench E8 — observability overhead: the same closed-loop fleet
//! serving run with instrumentation off, tracing on, and tracing +
//! telemetry sampling on (interleaved, best-of-N per mode), plus the
//! size of the exported Chrome trace.
//!
//! Run: `cargo bench --bench obs_bench`
//!
//! Emits `BENCH_obs.json` in the working directory so CI can archive
//! the overhead trajectory across PRs.

#![deny(deprecated)]

use tcd_npe::bench::{obs_bench, obs_json, render_obs, OBS_BENCH_REQUESTS, OBS_BENCH_RUNS};

fn main() {
    println!("=== observability: untraced vs traced vs traced+sampled serving ===");
    let b = obs_bench(OBS_BENCH_RUNS, OBS_BENCH_REQUESTS);
    println!("{}", render_obs(&b));

    let json = obs_json(&b);
    match std::fs::write("BENCH_obs.json", &json) {
        Ok(()) => println!("\nwrote BENCH_obs.json"),
        Err(e) => eprintln!("\ncould not write BENCH_obs.json: {e}"),
    }
}
