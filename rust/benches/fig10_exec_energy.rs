//! Bench E7/E8 — regenerates Fig. 10 (execution time and energy breakdown
//! of the four dataflows across the seven benchmarks) and times the sweep.
//! Also prints Table III and Table IV so every §IV artifact is covered by
//! `cargo bench`.
//!
//! Run: `cargo bench --bench fig10_exec_energy`

use tcd_npe::bench::{fig10_rows, render_fig10, render_table3, render_table4, BenchTimer};

fn main() {
    println!("=== Table III: TCD-NPE implementation PPA ===\n");
    println!("{}", render_table3());
    println!("=== Table IV: benchmark suite ===\n");
    println!("{}", render_table4());

    println!("=== Fig. 10: dataflow comparison across benchmarks ===\n");
    let rows = fig10_rows(tcd_npe::bench::fig10::FIG10_BATCHES);
    println!("{}", render_fig10(&rows));

    // Paper headline check, printed for EXPERIMENTS.md.
    println!("headline ratios (conv-OS time / TCD time per benchmark):");
    for chunk in rows.chunks(4) {
        println!(
            "  {:<16} {:.2}x time, {:.2}x energy",
            chunk[0].dataset,
            chunk[1].report.time_ns / chunk[0].report.time_ns,
            chunk[1].report.energy.on_chip_pj() / chunk[0].report.energy.on_chip_pj()
        );
    }

    let mut t = BenchTimer::new("fig10/full-sweep(B=10)");
    t.run(0, 3, || fig10_rows(10).len());
    println!("\n{}", t.report());
}
