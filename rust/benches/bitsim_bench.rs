//! Bench (substrate) — the bit-level arithmetic hot path: per-step cost of
//! the TCD-MAC vs conventional MAC functional models, and the CEL
//! reduction kernel that dominates both. This is the simulator's inner
//! loop, targeted by EXPERIMENTS.md §Perf.
//!
//! Run: `cargo bench --bench bitsim_bench`

use tcd_npe::bench::BenchTimer;
use tcd_npe::bitsim::compressor::cel_reduce;
use tcd_npe::bitsim::multiplier::{MultKind, PartialProducts};
use tcd_npe::tcdmac::MacKind;
use tcd_npe::util::SplitMix64;

fn main() {
    println!("=== MAC functional-model step cost ===");
    for kind in MacKind::table1_order() {
        let mut t = BenchTimer::new(format!("mac-step/{}", kind.name()));
        let mut rng = SplitMix64::new(1);
        let mut mac = kind.build();
        t.run(1, 5, || {
            for _ in 0..10_000 {
                mac.step(rng.next_i16(), rng.next_i16());
            }
            mac.finalize()
        });
        println!("{}  (per 10k steps)", t.report());
    }

    println!("\n=== partial-product generation ===");
    for kind in [
        MultKind::Simple,
        MultKind::BoothRadix2,
        MultKind::BoothRadix4,
        MultKind::BoothRadix8,
    ] {
        let pp = PartialProducts::new(kind, 40);
        let mut rng = SplitMix64::new(2);
        let mut t = BenchTimer::new(format!("ppgen/{}", kind.short()));
        t.run(1, 5, || {
            let mut acc = 0u64;
            for _ in 0..10_000 {
                acc ^= pp.rows(rng.next_i16(), rng.next_i16()).len() as u64;
            }
            acc
        });
        println!("{}  (per 10k ops)", t.report());
    }

    println!("\n=== CEL carry-save reduction ===");
    let mut rng = SplitMix64::new(3);
    for rows in [6usize, 8, 16, 18] {
        let data: Vec<u64> = (0..rows).map(|_| rng.next_u64()).collect();
        let mut t = BenchTimer::new(format!("cel-reduce/{rows}-rows"));
        t.run(1, 5, || {
            let mut acc = 0u64;
            for _ in 0..10_000 {
                let ((s, c), _) = cel_reduce(&data, 40);
                acc ^= s ^ c;
            }
            acc
        });
        println!("{}  (per 10k reductions)", t.report());
    }
}
