//! Bench E10 — the dataflow autotuner: every zoo model under all four
//! fixed dataflows vs the per-layer autotuned plan (cost-model cycles;
//! MLP rows are measured by actually executing both engines).
//!
//! Run: `cargo bench --bench dataflow_bench`
//!
//! Emits `BENCH_dataflow.json` in the working directory so CI can
//! archive the trajectory (autotuned speedup per zoo entry) across PRs.

use tcd_npe::bench::{dataflow_json, dataflow_rows, render_dataflow_table, DATAFLOW_BATCHES};

fn main() {
    println!("=== dataflow autotuner: fixed dataflows vs per-layer plan, full zoo ===");
    let rows = dataflow_rows(DATAFLOW_BATCHES);
    println!("{}", render_dataflow_table(&rows, DATAFLOW_BATCHES));

    for r in &rows {
        println!(
            "{:<14} {:<6} plan {:<16} {:>10} vs OS {:>10}  ({:.2}x)",
            r.network,
            r.family,
            r.plan,
            r.autotuned_cycles,
            r.os_cycles(),
            r.speedup()
        );
    }

    let json = dataflow_json(&rows, DATAFLOW_BATCHES);
    match std::fs::write("BENCH_dataflow.json", &json) {
        Ok(()) => println!("\nwrote BENCH_dataflow.json"),
        Err(e) => eprintln!("\ncould not write BENCH_dataflow.json: {e}"),
    }
}
