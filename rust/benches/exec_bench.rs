//! Bench E9 — the execution core: wall-clock of the `bitexact` / `fast` /
//! `parallel` roll backends over Table-IV MLPs, LeNet-5 and the DAG zoo.
//!
//! Run: `cargo bench --bench exec_bench`
//!
//! Emits `BENCH_exec.json` in the working directory so CI can archive
//! the trajectory (per-workload backend speedups) across PRs. Pin
//! `TCD_NPE_THREADS` for comparable numbers across runners.

use tcd_npe::bench::{exec_json, exec_rows, render_exec_table, EXEC_BATCHES};

fn main() {
    println!("=== execution core: roll-backend sweep ===");
    let rows = exec_rows(EXEC_BATCHES);
    println!("{}", render_exec_table(&rows, EXEC_BATCHES));

    let best_t4 = rows
        .iter()
        .filter(|r| r.table4)
        .map(|r| r.speedup_vs_bitexact())
        .fold(0.0f64, f64::max);
    println!(
        "best Table-IV parallel-vs-bitexact speedup: {best_t4:.0}x (acceptance bar: >=10x)"
    );
    assert!(
        rows.iter().all(|r| r.bit_identical),
        "a backend diverged from the Fix16 reference"
    );
    // The acceptance bar is enforced here, in release, so a performance
    // regression turns the CI exec job red instead of silently archiving
    // a bad trajectory.
    assert!(
        best_t4 >= 10.0,
        "Parallel backend no longer >=10x BitExact on any Table-IV workload ({best_t4:.1}x)"
    );

    let json = exec_json(&rows, EXEC_BATCHES);
    match std::fs::write("BENCH_exec.json", &json) {
        Ok(()) => println!("\nwrote BENCH_exec.json"),
        Err(e) => eprintln!("\ncould not write BENCH_exec.json: {e}"),
    }
}
