//! Bench E5/E6 — the Algorithm-1 mapper: schedule quality on the paper's
//! walkthroughs plus scheduling throughput (it runs per batch-arrival on
//! the coordinator's control path, so it must be fast).
//!
//! Run: `cargo bench --bench mapper_bench`

use tcd_npe::bench::BenchTimer;
use tcd_npe::mapper::{Gamma, MapperTree, NpeGeometry};
use tcd_npe::model::benchmarks;

fn main() {
    println!("=== Fig. 5/6 schedule quality ===");
    let mut m = MapperTree::new(NpeGeometry::WALKTHROUGH);
    for (b, u) in [(3usize, 9usize), (5, 7)] {
        let s = m.schedule_layer(Gamma::new(b, 100, u));
        println!(
            "Γ({b}, ·, {u}) on 6x3: {} rolls, {:.0}% utilization",
            s.total_rolls(),
            s.utilization() * 100.0
        );
    }

    println!("\n=== scheduling throughput ===");
    for bench in benchmarks() {
        for batches in [1usize, 10, 64] {
            let mut t = BenchTimer::new(format!(
                "schedule/{}/B={batches}",
                bench.dataset.replace(' ', "-")
            ));
            // Cold mapper each iteration: no memo reuse across runs.
            t.run(1, 10, || {
                let mut m = MapperTree::new(NpeGeometry::PAPER);
                m.schedule_model(&bench.topology, batches).total_rolls()
            });
            println!("{}", t.report());
        }
    }

    println!("\n=== memoization effect (MNIST, B=64) ===");
    let topo = &benchmarks()[0].topology;
    let mut cold = BenchTimer::new("mapper/cold");
    cold.run(1, 10, || {
        MapperTree::new(NpeGeometry::PAPER)
            .schedule_model(topo, 64)
            .total_rolls()
    });
    println!("{}", cold.report());
    let mut warm_mapper = MapperTree::new(NpeGeometry::PAPER);
    warm_mapper.schedule_model(topo, 64);
    let mut warm = BenchTimer::new("mapper/warm(memoized)");
    warm.run(1, 10, || warm_mapper.schedule_model(topo, 64).total_rolls());
    println!("{}", warm.report());
}
