//! Observability end-to-end: a traced service emits a valid,
//! Perfetto-loadable Chrome trace whose simulated-time spans carry exact
//! integer cycle arguments — per-batch child sums equal the engine's
//! reported `DataflowReport.cycles` — the sim side of the trace is
//! deterministic across seeded runs (only wall timestamps vary), and
//! `metrics_snapshot()` exports coherent Prometheus text and JSON.

use std::collections::HashMap;
use std::time::Duration;
use tcd_npe::coordinator::BatcherConfig;
use tcd_npe::dataflow::{DataflowEngine, OsEngine};
use tcd_npe::graph::QuantizedGraph;
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{benchmark_by_name, graph_benchmarks, QuantizedMlp};
use tcd_npe::obs::chrome::{SIM_PID, WALL_PID};
use tcd_npe::obs::{MetricsSnapshot, TraceLog};
use tcd_npe::serve::NpeService;
use tcd_npe::util::json::JsonValue;

fn iris() -> QuantizedMlp {
    let b = benchmark_by_name("Iris").expect("Iris is in Table IV");
    QuantizedMlp::synthesize(b.topology.clone(), 0x0B5_E2E)
}

/// Run `n` requests through a traced single-device service whose
/// batcher can only flush when full (30 s timer): exactly one batch of
/// `n`, in submission order — a fully deterministic sim-side workload.
fn one_batch_run(n: usize) -> (TraceLog, String, MetricsSnapshot) {
    let mlp = iris();
    let service = NpeService::builder(mlp.clone())
        .geometry(NpeGeometry::PAPER)
        .batcher(BatcherConfig::new(n, Duration::from_secs(30)))
        .tracing(true)
        .build()
        .expect("valid traced config");
    let inputs = mlp.synth_inputs(n, 0xDA7A);
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| service.submit(x.clone()).expect("admitted"))
        .collect();
    for t in tickets {
        t.wait_timeout(Duration::from_secs(30)).expect("answered");
    }
    let log = service.trace();
    let json = service.trace_json();
    let snap = service.metrics_snapshot();
    service.shutdown().expect("clean shutdown");
    (log, json, snap)
}

/// The acceptance bar: the cycles the trace attributes to a batch are
/// the engine's own report, bit for bit — proven by replaying the same
/// inputs through an offline engine.
#[test]
fn traced_batch_cycles_equal_the_engine_report() {
    let n = 8;
    let (log, _, _) = one_batch_run(n);
    assert_eq!(log.batches.len(), 1, "full-batch flush produced one batch");
    let bt = &log.batches[0];
    assert_eq!(bt.requests, n);
    assert!(!bt.profile.layers.is_empty(), "per-layer attribution present");

    let mlp = iris();
    let inputs = mlp.synth_inputs(n, 0xDA7A);
    let offline = OsEngine::tcd(NpeGeometry::PAPER).execute(&mlp, &inputs);
    assert_eq!(bt.cycles, offline.cycles, "trace cycles == engine-reported cycles");
    assert!(
        (bt.time_ns - offline.time_ns).abs() < 1e-6,
        "trace sim time == engine-reported time"
    );
    assert!(
        bt.profile.attributed_cycles() <= bt.cycles,
        "attribution never exceeds the engine total (the exporter emits \
         the remainder as an explicit overhead span)"
    );
    assert!(bt.profile.layers.iter().all(|l| l.deferred_cycles() > 0), "TCD tail per layer");
}

/// Full schema walk over a traced 2-device fleet serving a DAG-zoo
/// model: the export parses as JSON, every `B` has a matching `E` on
/// its (pid, tid) with LIFO nesting, and the integer `cycles` args of a
/// span's direct children sum exactly to the span's own — for every
/// batch and every layer in the trace.
#[test]
fn fleet_dag_trace_is_valid_and_sums_per_batch() {
    let bench = graph_benchmarks().into_iter().next().expect("DAG zoo is non-empty");
    let graph = QuantizedGraph::synthesize(bench.graph.clone(), 0xF1EE7);
    let service = NpeService::builder(graph.clone())
        .devices(vec![NpeGeometry::PAPER; 2])
        .batcher(BatcherConfig::new(4, Duration::from_millis(1)))
        .tracing(true)
        .build()
        .expect("valid traced fleet");
    let inputs = graph.synth_inputs(24, 0xDA7A);
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| service.submit(x.clone()).expect("admitted"))
        .collect();
    for t in tickets {
        t.wait_timeout(Duration::from_secs(30)).expect("answered");
    }
    let log = service.trace();
    let json = service.trace_json();
    service.shutdown().expect("clean shutdown");

    assert_eq!(log.dropped_events, 0, "nothing truncated at this scale");
    let v = JsonValue::parse(&json).expect("Chrome trace is valid JSON");
    let events = v.get("traceEvents").expect("traceEvents key").as_arr().expect("array");
    assert!(!events.is_empty());

    // Stack frame per (pid, tid): (name, declared cycles, child sum).
    let mut stacks: HashMap<(u64, u64), Vec<(String, u64, u64)>> = HashMap::new();
    let mut batches_checked = 0u64;
    let mut layers_checked = 0u64;
    let mut traced_batch_cycles = 0u64;
    for e in events {
        let ph = e.get("ph").unwrap().as_str().unwrap();
        let key = (
            e.get("pid").unwrap().as_u64().unwrap(),
            e.get("tid").unwrap().as_u64().unwrap(),
        );
        match ph {
            "B" => {
                let name = e.get("name").unwrap().as_str().unwrap().to_string();
                let cycles = e
                    .get("args")
                    .and_then(|a| a.get("cycles"))
                    .and_then(|c| c.as_u64())
                    .expect("every sim B span declares integer cycles");
                let stack = stacks.entry(key).or_default();
                if let Some(parent) = stack.last_mut() {
                    parent.2 += cycles;
                }
                stack.push((name, cycles, 0));
            }
            "E" => {
                let name = e.get("name").unwrap().as_str().unwrap();
                let (open, declared, children) = stacks
                    .get_mut(&key)
                    .and_then(Vec::pop)
                    .unwrap_or_else(|| panic!("E {name:?} without open B on {key:?}"));
                assert_eq!(open, name, "E closes the innermost B");
                if open.starts_with("batch ") {
                    assert_eq!(children, declared, "children of {open:?} sum to its cycles");
                    traced_batch_cycles += declared;
                    batches_checked += 1;
                } else if open.starts_with("layer ") {
                    assert_eq!(
                        children, declared,
                        "rounds + config switches of {open:?} sum to its cycles"
                    );
                    layers_checked += 1;
                }
            }
            "X" if key.0 == SIM_PID as u64 => {
                let name = e.get("name").unwrap().as_str().unwrap();
                // deferred-completion annotates the tail *inside* a
                // round's cycles; config-switch and overhead are the
                // additive children.
                if name != "deferred-completion" {
                    let cycles = e.get("args").unwrap().get("cycles").unwrap().as_u64().unwrap();
                    if let Some(parent) = stacks.entry(key).or_default().last_mut() {
                        parent.2 += cycles;
                    }
                }
            }
            _ => {}
        }
    }
    for (key, stack) in &stacks {
        assert!(stack.is_empty(), "unclosed spans on {key:?}: {stack:?}");
    }
    assert!(batches_checked > 0, "trace contains sim batches");
    assert!(layers_checked > 0, "trace contains sim layers");
    assert_eq!(
        traced_batch_cycles,
        log.batches.iter().map(|b| b.cycles).sum::<u64>(),
        "JSON batch cycles round-trip the recorded log"
    );
    // The wall side is present too: request-pipeline + device spans.
    assert!(
        events.iter().any(|e| {
            e.get("pid").unwrap().as_u64() == Some(WALL_PID as u64)
                && e.get("ph").unwrap().as_str() == Some("X")
        }),
        "wall spans exported on pid {WALL_PID}"
    );
}

/// Two identical seeded runs produce identical traces once the
/// wall-clock pid is stripped: the simulated side is a pure function of
/// (model, inputs, batching).
#[test]
fn sim_side_of_the_trace_is_deterministic() {
    fn sim_events(json: &str) -> Vec<JsonValue> {
        let v = JsonValue::parse(json).expect("valid trace JSON");
        v.get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .filter(|e| e.get("pid").unwrap().as_u64() != Some(WALL_PID as u64))
            .cloned()
            .collect()
    }
    let (_, json1, _) = one_batch_run(8);
    let (_, json2, _) = one_batch_run(8);
    let (a, b) = (sim_events(&json1), sim_events(&json2));
    assert!(!a.is_empty(), "sim side is non-empty");
    assert_eq!(a, b, "sim-side events identical across seeded runs");
}

/// `metrics_snapshot()` is one coherent export: counters, the latency
/// histogram, and the per-layer aggregation all line up with the raw
/// trace, in both Prometheus text and JSON form.
#[test]
fn metrics_snapshot_exports_prometheus_and_json() {
    let (log, _, snap) = one_batch_run(8);
    assert_eq!(snap.metrics.requests, 8);
    assert_eq!(snap.metrics.batches, 1);
    assert_eq!(snap.metrics.latencies.count(), 8);
    assert_eq!(snap.dropped_events, 0);
    assert!(!snap.layers.is_empty(), "per-layer aggregation present");
    let agg_rolls: u64 = snap.layers.iter().map(|l| l.rolls).sum();
    let log_rolls: u64 = log
        .batches
        .iter()
        .flat_map(|b| b.profile.layers.iter())
        .map(|l| l.rolls())
        .sum();
    assert_eq!(agg_rolls, log_rolls, "aggregation conserves rolls");
    assert!(
        snap.layers.iter().all(|l| l.deferred_cycles > 0),
        "the TCD deferred tail is visible per layer"
    );

    let text = snap.prometheus_text();
    assert!(text.contains("npe_requests_total 8"));
    assert!(text.contains("# TYPE npe_latency_us histogram"));
    assert!(text.contains("npe_latency_us_bucket{le=\"+Inf\"} 8"));
    assert!(text.contains("npe_latency_us_count 8"));
    assert!(text.contains("npe_layer_deferred_cycles_total{layer=\"0\"}"));

    let parsed = JsonValue::parse(&snap.to_json()).expect("snapshot JSON parses");
    assert_eq!(parsed.get("requests").unwrap().as_u64(), Some(8));
    assert_eq!(parsed.get("batches").unwrap().as_u64(), Some(1));
    assert_eq!(
        parsed.get("layers").unwrap().as_arr().unwrap().len(),
        snap.layers.len()
    );
}

/// An untraced service stays untraced: empty log, empty-but-valid
/// export, and trace ids pinned to 0 — the zero-overhead default.
#[test]
fn untraced_service_exports_empty_but_valid() {
    let mlp = iris();
    let service = NpeService::builder(mlp.clone())
        .geometry(NpeGeometry::PAPER)
        .batcher(BatcherConfig::new(4, Duration::from_millis(1)))
        .build()
        .expect("valid untraced config");
    let t = service.submit(mlp.synth_inputs(1, 1)[0].clone()).expect("admitted");
    t.wait_timeout(Duration::from_secs(30)).expect("answered");
    assert!(service.tracer().is_none());
    let log = service.trace();
    assert!(log.wall.is_empty() && log.batches.is_empty());
    let v = JsonValue::parse(&service.trace_json()).expect("still valid JSON");
    assert!(
        v.get("traceEvents")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .all(|e| e.get("ph").unwrap().as_str() == Some("M")),
        "an empty trace holds only process metadata"
    );
    let snap = service.metrics_snapshot();
    assert!(snap.layers.is_empty());
    assert_eq!(snap.metrics.requests, 1);
    service.shutdown().expect("clean shutdown");
}
