//! Differential fuzzing of the execution core: random Γ(B, I, U)
//! problems and random small graph topologies, `Parallel` backend vs
//! `BitExact` backend, bit-exact on outputs *and* cycle counts (and both
//! equal to the Fix16 reference forward pass).
//!
//! Harness: `util::check` — the repo's proptest stand-in (the offline
//! crate set has no proptest). It honors proptest's `PROPTEST_CASES`
//! environment knob (CI pins it) and replays the persisted regression
//! seeds in `proptest-regressions/exec_fuzz.txt` before the fresh
//! stream, so a once-found failure can never resurface silently. To
//! persist a new regression, append the `replay seed 0x…` printed by a
//! failing run to that file.

use tcd_npe::conv::{Conv2dLayer, Pool2dLayer, PoolKind, TensorShape};
use tcd_npe::dataflow::{best_conventional, DataflowEngine, DataflowReport, OsEngine};
use tcd_npe::exec::BackendKind;
use tcd_npe::graph::{GraphEngine, GraphModel, QuantizedGraph};
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{MlpTopology, QuantizedMlp};
use tcd_npe::tcdmac::MacKind;
use tcd_npe::util::check::{self, Gen};

const REGRESSIONS: &str = include_str!("../proptest-regressions/exec_fuzz.txt");

fn fuzz_cases() -> usize {
    check::env_cases(48)
}

/// A random NPE geometry small enough for the gate-level leg.
fn random_geometry(g: &mut Gen) -> NpeGeometry {
    NpeGeometry::new(g.usize_in(1, 6), g.usize_in(1, 4))
}

fn random_kind(g: &mut Gen) -> MacKind {
    if g.u64() & 1 == 0 {
        MacKind::Tcd
    } else {
        best_conventional()
    }
}

/// Differential contract: outputs and total cycles identical between
/// the two backends, outputs identical to the reference.
fn assert_differential(
    label: &str,
    reference: &[Vec<i16>],
    parallel: DataflowReport,
    bitexact: DataflowReport,
) {
    assert_eq!(parallel.outputs, bitexact.outputs, "{label}: backend outputs diverge");
    assert_eq!(parallel.cycles, bitexact.cycles, "{label}: backend cycles diverge");
    assert_eq!(parallel.outputs, reference, "{label}: outputs != Fix16 reference");
}

#[test]
fn fuzz_random_gamma_mlps_parallel_equals_bitexact() {
    check::cases_with_regressions(0xF0_2201, fuzz_cases(), REGRESSIONS, |g| {
        let geom = random_geometry(g);
        let kind = random_kind(g);
        // Random Γ(B, I, U), optionally stacked two transitions deep so
        // the ping-pong path fuzzes too.
        let b = g.usize_in(1, 6);
        let i = g.usize_in(1, 48);
        let u = g.usize_in(1, 16);
        let layers = if g.u64() & 1 == 0 {
            vec![i, u]
        } else {
            vec![i, u, g.usize_in(1, 8)]
        };
        let mlp = QuantizedMlp::synthesize(MlpTopology::new(layers), g.u64());
        let inputs = mlp.synth_inputs(b, g.u64());
        let reference = mlp.forward_batch(&inputs);
        let pa = OsEngine::new(geom, kind)
            .with_backend(BackendKind::Parallel)
            .execute(&mlp, &inputs);
        let bx = OsEngine::new(geom, kind)
            .with_backend(BackendKind::BitExact)
            .execute(&mlp, &inputs);
        let label = format!(
            "Γ(B={b}, I={i}, U={u}) {} on {}x{}",
            kind.name(),
            geom.tg_rows,
            geom.tg_cols
        );
        assert_differential(&label, &reference, pa, bx);
    });
}

/// A random small DAG: one of three topology families (chain CNN, twin
/// conv branches + concat, dense residual block), with randomized
/// shapes, kernels and widths. Construction-time shape inference keeps
/// every sample well-formed by construction.
fn random_graph(g: &mut Gen) -> GraphModel {
    let c = g.usize_in(1, 2);
    let hw = g.usize_in(4, 6);
    let mut gm = GraphModel::new(TensorShape::new(c, hw, hw));
    match g.usize_in(0, 2) {
        // Chain: conv → relu → [pool] → flatten → dense head.
        0 => {
            let k = g.usize_in(1, 3);
            let oc = g.usize_in(1, 4);
            let x = gm.conv(GraphModel::INPUT, Conv2dLayer::square(c, oc, k, k / 2));
            let x = gm.relu(x);
            let x = if g.u64() & 1 == 0 {
                gm.pool(x, Pool2dLayer::square(PoolKind::Max, 2))
            } else {
                x
            };
            let f = gm.flatten(x);
            let o = gm.dense(f, g.usize_in(1, 5));
            gm.set_output(o);
        }
        // Twin same-geometry conv branches (fused lowering merges them
        // into one Γ) → concat → flatten → dense head.
        1 => {
            let k = g.usize_in(1, 3);
            let conv = Conv2dLayer::square(c, g.usize_in(1, 3), k, k / 2);
            let a = gm.conv(GraphModel::INPUT, conv);
            let a = gm.relu(a);
            let b = gm.conv(GraphModel::INPUT, conv);
            let b = gm.relu(b);
            let cat = gm.concat(&[a, b]);
            let f = gm.flatten(cat);
            let o = gm.dense(f, g.usize_in(1, 5));
            gm.set_output(o);
        }
        // Dense residual block: fc(w) → relu → fc(w) → add → relu → head.
        _ => {
            let w = g.usize_in(1, 10);
            let f = gm.flatten(GraphModel::INPUT);
            let h = gm.dense(f, w);
            let h = gm.relu(h);
            let y = gm.dense(h, w);
            let s = gm.add(y, h);
            let s = gm.relu(s);
            let o = gm.dense(s, g.usize_in(1, 4));
            gm.set_output(o);
        }
    }
    gm
}

#[test]
fn fuzz_random_graphs_parallel_equals_bitexact() {
    check::cases_with_regressions(0xF0_2202, fuzz_cases(), REGRESSIONS, |g| {
        let geom = random_geometry(g);
        let kind = random_kind(g);
        let fuse = g.u64() & 1 == 0;
        let graph = random_graph(g);
        let q = QuantizedGraph::synthesize(graph, g.u64());
        let inputs = q.synth_inputs(g.usize_in(1, 4), g.u64());
        let reference = q.forward_batch(&inputs);
        let pa = GraphEngine::new(geom, kind)
            .fused(fuse)
            .with_backend(BackendKind::Parallel)
            .execute(&q, &inputs);
        let bx = GraphEngine::new(geom, kind)
            .fused(fuse)
            .with_backend(BackendKind::BitExact)
            .execute(&q, &inputs);
        let label = format!(
            "graph({} nodes, fuse={fuse}) {} on {}x{}",
            q.graph.n_nodes(),
            kind.name(),
            geom.tg_rows,
            geom.tg_cols
        );
        assert_differential(&label, &reference, pa, bx);
    });
}
