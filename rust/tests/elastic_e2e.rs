//! Elastic-pool end-to-end: a controller-resized fleet stays bit-exact
//! and lossless while it grows and shrinks.
//!
//! What is proven here, via the public serving API only:
//!
//! * a manual-tick load-step trajectory (grow under parked backlog,
//!   shrink after the drain) is **deterministic** — two identical
//!   seeded runs produce identical telemetry fingerprints, with the
//!   `pool_devices` column moving through the resizes;
//! * outputs are **bit-exact across resizes** — every response during a
//!   grow/shrink storm equals the model's reference forward pass;
//! * a shrink ordered mid-drain **never drops admitted work** — the
//!   retire pill waits for the victim's in-flight batch and the
//!   survivors absorb the queue;
//! * the controller respects its `[min, max]` bounds and its cooldown,
//!   and journals every resize as a structured `pool_resize` event.
//!
//! CI runs this file with pinned test threads (`--test-threads 2`):
//! the grow/shrink assertions reason about multi-thread drain windows,
//! and an oversubscribed runner would stretch those windows.

use std::time::{Duration, Instant};
use tcd_npe::coordinator::BatcherConfig;
use tcd_npe::fleet::ControllerConfig;
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{MlpTopology, QuantizedMlp};
use tcd_npe::obs::{EventKind, SamplerConfig};
use tcd_npe::serve::NpeService;

fn mlp(seed: u64) -> QuantizedMlp {
    QuantizedMlp::synthesize(MlpTopology::new(vec![8, 6, 2]), seed)
}

/// Wait out the post-response depth-release window (the slot frees
/// *after* the answer is sent).
fn quiesce(service: &NpeService) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while service.in_flight() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(service.in_flight(), 0, "service quiesces once everything is answered");
}

/// One seeded grow-then-shrink trajectory under a manual-tick controller
/// and sampler: park a backlog behind a huge batcher, let the controller
/// grow on its depth signal, flush, let it shrink on idleness, sampling
/// the timeline at each deterministic point. Returns the timeline
/// fingerprint and the sampled device counts.
fn load_step_run() -> (u64, Vec<u64>) {
    let model = mlp(0x57E9);
    // batch_size 64 with a 10s wait: submits park in the batcher, so the
    // controller's admission-depth signal is exact, not racy.
    let service = NpeService::builder(model.clone())
        .devices([NpeGeometry::PAPER])
        .elastic(1, 3)
        .controller(ControllerConfig::manual().with_cooldown(Duration::ZERO))
        .batcher(BatcherConfig::new(64, Duration::from_secs(10)))
        .telemetry(SamplerConfig::manual())
        .build()
        .expect("valid elastic service");
    let ctl = service.controller().expect("elastic service has a controller");
    let sampler = service.sampler().expect("telemetry enabled");
    let mut devices = Vec::new();
    let mut sample = |s: &std::sync::Arc<tcd_npe::obs::TelemetrySampler>| {
        s.tick();
        let snap = s.snapshot();
        devices.push(snap.latest().expect("ticked").pool_devices);
    };

    sample(&sampler); // tick 0: idle, 1 device
    // Park 12 requests: depth/device = 12 > 4 → grow on each tick
    // (zero cooldown) until max.
    let inputs = model.synth_inputs(12, 0xDA7A);
    let expect = model.forward_batch(&inputs);
    let tickets: Vec<_> = inputs
        .into_iter()
        .map(|x| service.submit(x).expect("admitted"))
        .collect();
    ctl.tick();
    sample(&sampler); // tick 1: grown to 2, backlog still parked
    ctl.tick();
    sample(&sampler); // tick 2: grown to 3 (max)
    ctl.tick();
    assert_eq!(ctl.pool_size(), 3, "bounded at max even with the signal still high");

    // Flush the parked backlog through the grown pool and verify every
    // answer against the reference forward pass.
    drop(service); // drop flushes: the batcher drains into the pool
    for (t, want) in tickets.into_iter().zip(expect) {
        let resp = t.wait_timeout(Duration::from_secs(30)).expect("flushed");
        assert_eq!(resp.output, want, "bit-exact across the grow");
    }
    (sampler.snapshot().fingerprint(), devices)
}

#[test]
fn load_step_trajectory_is_deterministic() {
    let (fp_a, dev_a) = load_step_run();
    let (fp_b, dev_b) = load_step_run();
    assert_eq!(dev_a, dev_b, "device-count trajectory repeats");
    assert_eq!(fp_a, fp_b, "timeline fingerprints match across identical runs");
    // The trajectory itself: 1 device idle, then 2, then 3 under the
    // parked backlog (ticks sampled before any request is answered).
    assert_eq!(&dev_a[..3], &[1, 2, 3], "pool_devices column tracks the grows");
}

#[test]
fn outputs_stay_bit_exact_across_a_resize_storm() {
    let model = mlp(0xB17E);
    let service = NpeService::builder(model.clone())
        .devices([NpeGeometry::PAPER])
        .elastic(1, 4)
        .controller(ControllerConfig::manual())
        .batcher(BatcherConfig::new(4, Duration::from_micros(200)))
        .build()
        .expect("valid elastic service");
    let ctl = service.controller().expect("controller present");
    // Fixed-size reference for the same inputs.
    let inputs = model.synth_inputs(48, 0x5EED);
    let expect = model.forward_batch(&inputs);
    for (wave, chunk) in inputs.chunks(8).enumerate() {
        // Resize between (and under) waves: 1 → 4 → 2 → 3 → 1 → 4.
        let target = [1, 4, 2, 3, 1, 4][wave % 6];
        ctl.force(target);
        let tickets: Vec<_> = chunk
            .iter()
            .map(|x| service.submit(x.clone()).expect("admitted"))
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let resp = t.wait_timeout(Duration::from_secs(30)).expect("answered");
            assert_eq!(
                resp.output,
                expect[wave * 8 + i],
                "wave {wave} request {i} bit-exact at pool size {target}"
            );
        }
    }
    quiesce(&service);
    service.shutdown().expect("clean shutdown");
}

#[test]
fn shrink_during_drain_drops_nothing() {
    let model = mlp(0xD0D0);
    let service = NpeService::builder(model.clone())
        .devices([NpeGeometry::PAPER, NpeGeometry::PAPER, NpeGeometry::PAPER])
        .elastic(1, 3)
        .controller(ControllerConfig::manual())
        .batcher(BatcherConfig::new(2, Duration::from_micros(100)))
        .journaling(256)
        .build()
        .expect("valid elastic service");
    let ctl = service.controller().expect("controller present");
    let inputs = model.synth_inputs(64, 0xFEED);
    let expect = model.forward_batch(&inputs);
    // Admit everything first (Block admission: nothing is refused), then
    // order a shrink to min while the queue is still draining. The two
    // retiring devices must finish their in-flight batches; the queued
    // jobs drain through the survivor.
    let tickets: Vec<_> = inputs
        .into_iter()
        .map(|x| service.submit(x).expect("admitted"))
        .collect();
    assert_eq!(ctl.force(1), 1, "shrink-to-min lands mid-drain");
    for (t, want) in tickets.into_iter().zip(expect) {
        let resp = t.wait_timeout(Duration::from_secs(30)).expect("never dropped");
        assert_eq!(resp.output, want, "answers stay bit-exact through the shrink");
    }
    let journal = service.journal().expect("journaling on");
    let resizes = journal
        .events()
        .iter()
        .filter(|e| e.kind == EventKind::PoolResize)
        .count();
    assert!(resizes >= 2, "both shrink steps journaled, saw {resizes}");
    quiesce(&service);
    service.shutdown().expect("clean shutdown");
}

#[test]
fn bounds_and_cooldown_are_respected() {
    let model = mlp(0xC001);
    // Cooldown effectively infinite: after the first (free) resize the
    // policy loop must hold even though the signal stays high.
    let service = NpeService::builder(model.clone())
        .devices([NpeGeometry::PAPER])
        .elastic(1, 3)
        .controller(ControllerConfig::manual().with_cooldown(Duration::from_secs(3600)))
        .batcher(BatcherConfig::new(64, Duration::from_secs(10)))
        .journaling(256)
        .build()
        .expect("valid elastic service");
    let ctl = service.controller().expect("controller present");
    assert_eq!((ctl.min_devices(), ctl.max_devices()), (1, 3));

    // Park a deep backlog: depth/device stays far above the threshold.
    let tickets: Vec<_> = model
        .synth_inputs(16, 0xDA7A)
        .into_iter()
        .map(|x| service.submit(x).expect("admitted"))
        .collect();
    for _ in 0..5 {
        ctl.tick();
    }
    assert_eq!(
        ctl.pool_size(),
        2,
        "exactly one grow: the first resize is free, the cooldown gates the rest"
    );

    // Forced resizes clamp to the bounds, never past them.
    assert_eq!(ctl.force(100), 3, "force clamps to max");
    assert_eq!(ctl.force(0), 1, "force clamps to min");

    let journal = service.journal().expect("journaling on");
    let resizes: Vec<_> = journal
        .events()
        .into_iter()
        .filter(|e| e.kind == EventKind::PoolResize)
        .collect();
    // 1 policy grow + 1 forced grow + 2 forced shrinks = 4 events.
    assert_eq!(resizes.len(), 4, "every resize journaled: {resizes:?}");

    drop(service); // flush the parked backlog
    for t in tickets {
        t.wait_timeout(Duration::from_secs(30)).expect("flushed on drop");
    }
}
