//! End-to-end property tests for the dataflow autotuner: the analytical
//! cost model's predicted cycle counts must equal the measured
//! [`DataflowReport`] cycles **exactly** — for all four dataflows, on
//! random geometries, MAC kinds, topologies and batch sizes. The model
//! consumes the same closed forms the engines report from (see
//! `autotune::cost` module docs), so any drift between prediction and
//! measurement is a bug in one of them, not tolerable noise.
//!
//! Harness: `util::check` — the repo's proptest stand-in. It honors the
//! `PROPTEST_CASES` environment knob and replays the persisted
//! regression seeds in `proptest-regressions/autotune_e2e.txt` before
//! the fresh stream. To persist a new regression, append the
//! `replay seed 0x…` printed by a failing run to that file.

use tcd_npe::autotune::{plan_mlp, AutotunedEngine, CostModel, Objective};
use tcd_npe::dataflow::{
    best_conventional, DataflowEngine, NlrEngine, OsEngine, RnaEngine, WsEngine,
};
use tcd_npe::mapper::{Dataflow, Gamma, NpeGeometry};
use tcd_npe::model::{MlpTopology, QuantizedMlp};
use tcd_npe::tcdmac::MacKind;
use tcd_npe::util::check::{self, Gen};

const REGRESSIONS: &str = include_str!("../proptest-regressions/autotune_e2e.txt");

fn prop_cases() -> usize {
    check::env_cases(32)
}

fn random_geometry(g: &mut Gen) -> NpeGeometry {
    NpeGeometry::new(g.usize_in(1, 6), g.usize_in(1, 4))
}

fn random_kind(g: &mut Gen) -> MacKind {
    if g.u64() & 1 == 0 {
        MacKind::Tcd
    } else {
        best_conventional()
    }
}

/// A random 1–2-transition MLP topology sized so every dataflow's
/// engine leg stays fast.
fn random_topology(g: &mut Gen) -> MlpTopology {
    let i = g.usize_in(1, 48);
    let u = g.usize_in(1, 16);
    let layers = if g.u64() & 1 == 0 {
        vec![i, u]
    } else {
        vec![i, u, g.usize_in(1, 8)]
    };
    MlpTopology::new(layers)
}

/// The model's whole-MLP prediction for one fixed dataflow: per-layer
/// costs summed over the topology's Γ transitions (no switches).
fn predicted_total(model: &mut CostModel, topo: &MlpTopology, b: usize, d: Dataflow) -> u64 {
    topo.transitions()
        .map(|(i, u)| model.layer_cost(Gamma::new(b, i, u), d).cycles)
        .sum()
}

/// predicted == measured, exactly, for every fixed dataflow on random
/// (geometry, kind, topology, B).
#[test]
fn prop_predicted_cycles_equal_measured_for_every_dataflow() {
    check::cases_with_regressions(0xA0_70_01, prop_cases(), REGRESSIONS, |g| {
        let geom = random_geometry(g);
        let kind = random_kind(g);
        let topo = random_topology(g);
        let b = g.usize_in(1, 6);
        let mlp = QuantizedMlp::synthesize(topo.clone(), g.u64());
        let inputs = mlp.synth_inputs(b, g.u64());
        let mut model = CostModel::with_kind(geom, kind);
        let label = |d: Dataflow| {
            format!(
                "{} on {}x{} kind={} topo={:?} b={b}",
                d.name(),
                geom.tg_rows,
                geom.tg_cols,
                kind.name(),
                topo.layers
            )
        };
        // OS/WS run on the model's MAC kind; NLR/RNA always run (and are
        // priced) on the best conventional MAC — so `new` is correct.
        let os = OsEngine::new(geom, kind).execute(&mlp, &inputs);
        assert_eq!(
            predicted_total(&mut model, &topo, b, Dataflow::Os),
            os.cycles,
            "{}",
            label(Dataflow::Os)
        );
        let ws = WsEngine::with_kind(geom, kind).execute(&mlp, &inputs);
        assert_eq!(
            predicted_total(&mut model, &topo, b, Dataflow::Ws),
            ws.cycles,
            "{}",
            label(Dataflow::Ws)
        );
        let nlr = NlrEngine::new(geom).execute(&mlp, &inputs);
        assert_eq!(
            predicted_total(&mut model, &topo, b, Dataflow::Nlr),
            nlr.cycles,
            "{}",
            label(Dataflow::Nlr)
        );
        let rna = RnaEngine::new(geom).execute(&mlp, &inputs);
        assert_eq!(
            predicted_total(&mut model, &topo, b, Dataflow::Rna),
            rna.cycles,
            "{}",
            label(Dataflow::Rna)
        );
    });
}

/// The autotuned engine's measured report equals its own plan's
/// prediction, the plan never loses to the fixed-OS baseline, and the
/// executed outputs stay bit-identical to the Fix16 reference.
#[test]
fn prop_autotuned_plan_is_exact_and_never_worse_than_os() {
    check::cases_with_regressions(0xA0_70_02, prop_cases(), REGRESSIONS, |g| {
        let geom = random_geometry(g);
        let kind = random_kind(g);
        let topo = random_topology(g);
        let b = g.usize_in(1, 6);
        let mlp = QuantizedMlp::synthesize(topo.clone(), g.u64());
        let inputs = mlp.synth_inputs(b, g.u64());
        let reference = mlp.forward_batch(&inputs);
        let mut model = CostModel::with_kind(geom, kind);
        let plan = plan_mlp(&mut model, Objective::Cycles, &topo, b);
        let os_total = predicted_total(&mut model, &topo, b, Dataflow::Os);
        assert!(
            plan.total_cycles() <= os_total,
            "plan {} ({}) must not lose to all-OS ({os_total}) on {}x{} topo={:?} b={b}",
            plan.summary(),
            plan.total_cycles(),
            geom.tg_rows,
            geom.tg_cols,
            topo.layers
        );
        let r = AutotunedEngine::with_kind(geom, kind).execute(&mlp, &inputs);
        assert_eq!(
            r.cycles,
            plan.total_cycles(),
            "autotuned report must equal its plan's prediction ({})",
            plan.summary()
        );
        assert_eq!(r.outputs, reference, "autotuned outputs != Fix16 reference");
    });
}
