//! Cross-engine conformance: every zoo model (Table-IV MLPs + CNN zoo +
//! DAG zoo) × MAC kind {TCD, conventional} × geometry {16×8, 8×4, 3×3}
//! × roll backend {bitexact, fast, parallel} must produce outputs
//! bit-identical to the Fix16 reference forward pass, with a
//! backend-invariant cycle count — one macro-generated suite certifying
//! the whole `exec::ExecCore` dispatch path for all engines at once.
//!
//! MAC-kind cycle relation (asserted per model × geometry): a TCD roll
//! pays exactly one extra carry-propagation cycle, so raw counts obey
//! `tcd.cycles > conv.cycles` while the execution *time* — cycles × the
//! MAC's achievable clock, the paper's headline metric — obeys
//! `tcd.time_ns ≤ conv.time_ns`: the TCD-NPE never costs more than the
//! conventional NPE on any workload/geometry in the zoo.
//!
//! Batch counts are scaled per model so the gate-level `bitexact` leg
//! stays tractable (MNIST-class models run B=2, small ones B=4); every
//! backend runs at the same B so cycle counts are comparable.

use tcd_npe::conv::{CnnEngine, QuantizedCnn};
use tcd_npe::dataflow::{best_conventional, DataflowEngine, DataflowReport, OsEngine};
use tcd_npe::exec::BackendKind;
use tcd_npe::graph::{GraphEngine, QuantizedGraph};
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::zoo;
use tcd_npe::model::{benchmark_by_name, QuantizedMlp};
use tcd_npe::tcdmac::MacKind;

/// The swept geometries: the paper's NPE, a mid square-ish array, and a
/// minimal 3×3 (configs (1,9) and (3,3) only).
fn geometries() -> [NpeGeometry; 3] {
    [
        NpeGeometry::PAPER,
        NpeGeometry { tg_rows: 8, tg_cols: 4 },
        NpeGeometry { tg_rows: 3, tg_cols: 3 },
    ]
}

/// Drive one model through every kind × geometry × backend cell and
/// assert the conformance contract. `exec` runs the model's engine for
/// one cell; `reference` is the Fix16 forward pass.
fn assert_conformance(
    label: &str,
    reference: &[Vec<i16>],
    mut exec: impl FnMut(MacKind, NpeGeometry, BackendKind) -> DataflowReport,
) {
    for geom in geometries() {
        let mut per_kind: Vec<(MacKind, DataflowReport)> = Vec::new();
        for kind in [MacKind::Tcd, best_conventional()] {
            let mut cell_cycles = None;
            let mut last = None;
            for backend in BackendKind::ALL {
                let r = exec(kind, geom, backend);
                assert_eq!(
                    r.outputs,
                    reference,
                    "{label}: {} on {}x{} via {} != reference",
                    kind.name(),
                    geom.tg_rows,
                    geom.tg_cols,
                    backend.name()
                );
                match cell_cycles {
                    None => cell_cycles = Some(r.cycles),
                    Some(c) => assert_eq!(
                        c,
                        r.cycles,
                        "{label}: cycle count must be backend-invariant ({} on {}x{}, {})",
                        kind.name(),
                        geom.tg_rows,
                        geom.tg_cols,
                        backend.name()
                    ),
                }
                last = Some(r);
            }
            per_kind.push((kind, last.expect("three backends ran")));
        }
        let (_, tcd) = &per_kind[0];
        let (_, conv) = &per_kind[1];
        assert!(
            tcd.cycles > conv.cycles,
            "{label} on {}x{}: TCD pays one CPM cycle per roll ({} vs {})",
            geom.tg_rows,
            geom.tg_cols,
            tcd.cycles,
            conv.cycles
        );
        assert!(
            tcd.time_ns <= conv.time_ns,
            "{label} on {}x{}: TCD execution time {:.0}ns exceeds conventional {:.0}ns",
            geom.tg_rows,
            geom.tg_cols,
            tcd.time_ns,
            conv.time_ns
        );
    }
}

fn mlp_conformance(dataset: &str, batches: usize) {
    let b = benchmark_by_name(dataset).expect("Table-IV row");
    let mlp = QuantizedMlp::synthesize(b.topology.clone(), 0xC0F0);
    let inputs = mlp.synth_inputs(batches, 0xC0F1);
    let reference = mlp.forward_batch(&inputs);
    assert_conformance(dataset, &reference, |kind, geom, backend| {
        OsEngine::new(geom, kind)
            .with_backend(backend)
            .execute(&mlp, &inputs)
    });
}

fn cnn_conformance(network: &str, batches: usize) {
    let b = zoo::cnn_benchmark_by_name(network).expect("CNN zoo row");
    let cnn = QuantizedCnn::synthesize(b.topology.clone(), 0xC0F2);
    let inputs = cnn.synth_inputs(batches, 0xC0F3);
    let reference = cnn.forward_batch(&inputs);
    assert_conformance(network, &reference, |kind, geom, backend| {
        CnnEngine::new(geom, kind)
            .with_backend(backend)
            .execute(&cnn, &inputs)
    });
}

fn graph_conformance(network: &str, batches: usize) {
    let b = zoo::graph_benchmark_by_name(network).expect("DAG zoo row");
    let q = QuantizedGraph::synthesize(b.graph.clone(), 0xC0F4);
    let inputs = q.synth_inputs(batches, 0xC0F5);
    let reference = q.forward_batch(&inputs);
    assert_conformance(network, &reference, |kind, geom, backend| {
        GraphEngine::new(geom, kind)
            .with_backend(backend)
            .execute(&q, &inputs)
    });
}

macro_rules! conformance_suite {
    ($($name:ident: $family:ident($model:expr, $batches:expr);)+) => {
        $(
            #[test]
            fn $name() {
                $family($model, $batches);
            }
        )+
    };
}

conformance_suite! {
    // Table-IV MLP zoo (MNIST-class rows run B=2 to keep the gate-level
    // leg tractable; the small UCI rows run B=4).
    conformance_mlp_mnist: mlp_conformance("MNIST", 2);
    conformance_mlp_adult: mlp_conformance("Adult", 4);
    conformance_mlp_fft: mlp_conformance("Mibench data", 4);
    conformance_mlp_wine: mlp_conformance("Wine", 4);
    conformance_mlp_iris: mlp_conformance("Iris", 4);
    conformance_mlp_poker: mlp_conformance("Poker Hands", 4);
    conformance_mlp_fashion_mnist: mlp_conformance("Fashion MNIST", 2);
    // CNN zoo (im2col lowering blows B up to B·P GEMM rows — B=1 is
    // already hundreds of rows per conv layer).
    conformance_cnn_lenet5: cnn_conformance("LeNet-5", 1);
    conformance_cnn_cifarnet: cnn_conformance("CifarNet", 1);
    // DAG zoo (fused lowering on, the production path).
    conformance_graph_resmlp: graph_conformance("ResMLP", 4);
    conformance_graph_tiny_resnet: graph_conformance("TinyResNet", 2);
    conformance_graph_inception_mini: graph_conformance("InceptionMini", 2);
}

/// Every evaluated dataflow — the fixed WS/NLR/RNA engines and the
/// autotuned per-layer mix — rides the same conformance contract as OS:
/// outputs bit-identical to the Fix16 reference across zoo model × MAC
/// kind × geometry × backend, with a backend-invariant cycle count.
/// Dataflow moves data, it does not change math. (MNIST runs B=1 here:
/// this sweep multiplies the gate-level leg by 4 engines × 2 kinds.)
#[test]
fn conformance_mlp_every_dataflow() {
    use tcd_npe::autotune::AutotunedEngine;
    use tcd_npe::dataflow::{NlrEngine, RnaEngine, WsEngine};
    type Run = fn(NpeGeometry, MacKind, BackendKind, &QuantizedMlp, &[Vec<i16>]) -> DataflowReport;
    let engines: [(&str, Run); 4] = [
        ("ws", |g, k, bk, m, x| WsEngine::with_kind(g, k).with_backend(bk).execute(m, x)),
        ("nlr", |g, k, bk, m, x| NlrEngine::with_kind(g, k).with_backend(bk).execute(m, x)),
        ("rna", |g, k, bk, m, x| RnaEngine::with_kind(g, k).with_backend(bk).execute(m, x)),
        ("autotuned", |g, k, bk, m, x| {
            AutotunedEngine::with_kind(g, k).with_backend(bk).execute(m, x)
        }),
    ];
    for (dataset, batches) in [("Iris", 4), ("Wine", 4), ("MNIST", 1)] {
        let b = benchmark_by_name(dataset).expect("Table-IV row");
        let mlp = QuantizedMlp::synthesize(b.topology.clone(), 0xC0F0);
        let inputs = mlp.synth_inputs(batches, 0xC0F1);
        let reference = mlp.forward_batch(&inputs);
        for geom in geometries() {
            for kind in [MacKind::Tcd, best_conventional()] {
                for (name, run) in engines {
                    let mut cell_cycles = None;
                    for backend in BackendKind::ALL {
                        let r = run(geom, kind, backend, &mlp, &inputs);
                        assert_eq!(
                            r.outputs,
                            reference,
                            "{dataset}: {name} ({}) on {}x{} via {} != reference",
                            kind.name(),
                            geom.tg_rows,
                            geom.tg_cols,
                            backend.name()
                        );
                        match cell_cycles {
                            None => cell_cycles = Some(r.cycles),
                            Some(c) => assert_eq!(
                                c,
                                r.cycles,
                                "{dataset}: {name} ({}) cycles must be backend-invariant on {}x{}",
                                kind.name(),
                                geom.tg_rows,
                                geom.tg_cols
                            ),
                        }
                    }
                }
            }
        }
    }
}

/// The unfused graph lowering must conform too (it schedules per node
/// instead of per merged group — different rolls, same math).
#[test]
fn conformance_graph_unfused_lowering() {
    for b in zoo::graph_benchmarks() {
        let q = QuantizedGraph::synthesize(b.graph.clone(), 0xC0F6);
        let inputs = q.synth_inputs(2, 0xC0F7);
        let reference = q.forward_batch(&inputs);
        for backend in BackendKind::ALL {
            let r = GraphEngine::tcd(NpeGeometry::PAPER)
                .fused(false)
                .with_backend(backend)
                .execute(&q, &inputs);
            assert_eq!(r.outputs, reference, "{} unfused via {}", b.network, backend.name());
        }
    }
}
