//! Live-telemetry end-to-end: the manual-tick sampler is deterministic
//! across runs of the same seeded load, per-device occupancy reflects
//! real busy windows (in (0, 1] under load, exactly 0 when idle), the
//! SLO math is exact on a hand-built histogram and surfaces through the
//! service, serving events land in the bounded journal with overflow
//! accounted, and the multi-tenant registry merges all of it into one
//! well-formed exposition.
//!
//! CI runs this file with pinned test threads (`--test-threads 2`): the
//! occupancy and quiescence assertions reason about wall-time windows,
//! and an oversubscribed runner would make those windows lie.

use std::time::{Duration, Instant};
use tcd_npe::coordinator::BatcherConfig;
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{MlpTopology, QuantizedMlp};
use tcd_npe::obs::{EventKind, LogHistogram, SamplerConfig, SloConfig, SloTracker};
use tcd_npe::serve::{AdmissionPolicy, ModelRegistry, NpeService, ServeError};
use tcd_npe::util::json::JsonValue;

fn mlp(seed: u64) -> QuantizedMlp {
    QuantizedMlp::synthesize(MlpTopology::new(vec![8, 6, 2]), seed)
}

/// The depth slot is released *after* the response send (the responder's
/// drop), so a woken client can observe `in_flight() == 1` for a moment.
/// Telemetry ticks that want load-determined gauges must wait out that
/// window.
fn quiesce(in_flight: impl Fn() -> usize) {
    let deadline = Instant::now() + Duration::from_secs(10);
    while in_flight() > 0 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(in_flight(), 0, "service quiesces after every ticket answered");
}

/// One seeded three-wave run against a manual-tick sampler, ticked only
/// at fully quiesced points. Returns the timeline fingerprint and the
/// per-tick answered totals.
fn seeded_wave_run() -> (u64, Vec<u64>) {
    let model = mlp(0x5EED);
    let service = NpeService::builder(model.clone())
        .devices(vec![NpeGeometry::PAPER; 2])
        .batcher(BatcherConfig::new(4, Duration::from_micros(200)))
        .telemetry(SamplerConfig::manual())
        .build()
        .expect("valid service");
    let sampler = service.sampler().expect("telemetry enabled");
    sampler.tick(); // tick 0: idle baseline
    for wave in 0u64..3 {
        let inputs = model.synth_inputs(8, 0xDA7A ^ wave);
        let tickets: Vec<_> = inputs
            .into_iter()
            .map(|x| service.submit(x).expect("admitted"))
            .collect();
        for t in tickets {
            t.wait_timeout(Duration::from_secs(30)).expect("answered");
        }
        quiesce(|| service.in_flight());
        sampler.tick(); // gauges at this point are load-determined
    }
    let snap = sampler.snapshot();
    let answered: Vec<u64> = snap.samples.iter().map(|s| s.answered_total).collect();
    let fp = snap.fingerprint();
    service.shutdown().expect("clean shutdown");
    (fp, answered)
}

#[test]
fn manual_tick_timeline_is_identical_across_runs() {
    let (fp1, answered1) = seeded_wave_run();
    let (fp2, answered2) = seeded_wave_run();
    assert_eq!(answered1, vec![0, 8, 16, 24], "quiesced ticks read exact totals");
    assert_eq!(answered1, answered2);
    assert_eq!(fp1, fp2, "same seeded load at the same tick points = same fingerprint");
}

#[test]
fn occupancy_is_positive_under_load_and_zero_idle() {
    let model = mlp(0x0CC);
    let service = NpeService::builder(model.clone())
        .devices(vec![NpeGeometry::PAPER]) // one device: it must do all the work
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .telemetry(SamplerConfig::manual())
        .build()
        .expect("valid service");
    let sampler = service.sampler().expect("telemetry enabled");
    sampler.tick(); // baseline for the busy delta
    let tickets: Vec<_> = model
        .synth_inputs(32, 0xDA7A)
        .into_iter()
        .map(|x| service.submit(x).expect("admitted"))
        .collect();
    for t in tickets {
        t.wait_timeout(Duration::from_secs(30)).expect("answered");
    }
    quiesce(|| service.in_flight());
    sampler.tick();
    let snap = sampler.snapshot();
    let occ = snap.latest().expect("ticked").occupancy.clone();
    assert_eq!(occ.len(), 1);
    assert!(
        occ[0] > 0.0 && occ[0] <= 1.0,
        "window covering 32 executions has occupancy in (0, 1], got {}",
        occ[0]
    );
    // A window in which the device never executed is exactly zero.
    std::thread::sleep(Duration::from_millis(5));
    sampler.tick();
    let occ = sampler.snapshot().latest().expect("ticked").occupancy.clone();
    assert_eq!(occ, vec![0.0], "idle window is exactly zero");
    service.shutdown().expect("clean shutdown");
}

#[test]
fn slo_math_is_exact_and_surfaces_through_the_service() {
    // Hand-built histogram: 90 answers at 10 µs, 10 at 1024 µs — all far
    // from the 16 µs objective's bucket boundary, so counts are exact.
    let mut h = LogHistogram::new();
    for _ in 0..90 {
        h.record(10_000);
    }
    for _ in 0..10 {
        h.record(1_024_000);
    }
    let tracker = SloTracker::new(SloConfig::new(16, 0.95));
    let s = tracker.evaluate(&h);
    assert_eq!((s.good, s.bad), (90, 10));
    assert!((s.compliance - 0.90).abs() < 1e-12);
    // Allowed bad fraction 5 %, observed 10 % → burn rate exactly 2.
    assert!((s.burn_rate - 2.0).abs() < 1e-12);
    assert!(s.exhausted());

    // End to end: a served workload under a generous objective is fully
    // compliant with zero burn, and the status reaches the exposition.
    let model = mlp(0x510);
    let service = NpeService::builder(model.clone())
        .geometry(NpeGeometry::PAPER)
        .batcher(BatcherConfig::new(4, Duration::from_micros(200)))
        .slo(SloConfig::new(60_000_000, 0.99))
        .build()
        .expect("valid service");
    for x in model.synth_inputs(8, 0xDA7A) {
        service
            .submit(x)
            .expect("admitted")
            .wait_timeout(Duration::from_secs(30))
            .expect("answered");
    }
    let status = service.slo_status().expect("slo configured");
    assert_eq!(status.total(), 8);
    assert_eq!(status.good, 8);
    assert_eq!(status.compliance, 1.0);
    assert_eq!(status.burn_rate, 0.0);
    assert!(!status.exhausted());
    let text = service.metrics_snapshot().prometheus_text();
    assert!(text.contains("npe_slo_objective_us 60000000"));
    assert!(text.contains("npe_slo_good_total 8"));
    assert!(text.contains("npe_slo_compliance 1.000000"));
    service.shutdown().expect("clean shutdown");
}

#[test]
fn admission_rejects_journal_with_overflow_accounting() {
    let model = mlp(0x10C);
    // max_batch 64 + a 200 ms flush timer: the first admitted request
    // parks in the batcher, holding the single depth slot, while the
    // following submits (microseconds later) are all refused.
    let service = NpeService::builder(model.clone())
        .geometry(NpeGeometry::PAPER)
        .batcher(BatcherConfig::new(64, Duration::from_millis(200)))
        .admission(AdmissionPolicy::Reject { max_depth: 1 })
        .journaling(4)
        .label("iris")
        .build()
        .expect("valid service");
    let inputs = model.synth_inputs(16, 0xDA7A);
    let first = service.submit(inputs[0].clone()).expect("first admitted");
    let mut rejected = 0usize;
    for x in &inputs[1..] {
        match service.submit(x.clone()) {
            Err(ServeError::QueueFull { .. }) => rejected += 1,
            Ok(t) => drop(t), // only possible if the batch flushed early
            Err(e) => panic!("unexpected submit error: {e:?}"),
        }
    }
    assert!(rejected >= 5, "depth bound 1 holds while the batcher waits, got {rejected}");
    first.wait_timeout(Duration::from_secs(30)).expect("answered");
    let journal = service.journal().expect("journaling enabled");
    let events = journal.events();
    assert!(events.len() <= 4, "journal stays at its capacity");
    assert_eq!(
        events.len() + journal.dropped() as usize,
        rejected,
        "every refusal journaled; displaced events counted, not lost silently"
    );
    assert!(journal.dropped() >= 1, "16 submits against capacity 4 must overflow");
    // The *newest* events survive; monotonic sequence numbers show the
    // gap left by the dropped oldest ones.
    let seqs: Vec<u64> = events.iter().map(|e| e.seq).collect();
    assert!(seqs.windows(2).all(|w| w[0] < w[1]), "sequence stays monotonic: {seqs:?}");
    assert_eq!(seqs.last().copied(), Some(rejected as u64 - 1), "newest event retained");
    for e in &events {
        assert_eq!(e.kind, EventKind::AdmissionReject);
        assert_eq!(e.tenant.as_deref(), Some("iris"), "sink carries the service label");
        assert!(e.render().contains("admission_reject"), "{}", e.render());
    }
    assert_eq!(journal.events_for("iris").len(), events.len());
    assert!(journal.events_for("other").is_empty());
    service.shutdown().expect("clean shutdown");
}

#[test]
fn registry_merges_tenant_slo_and_fleet_telemetry() {
    let (a, b) = (mlp(10), mlp(20));
    let registry = ModelRegistry::builder()
        .devices([NpeGeometry::PAPER, NpeGeometry::PAPER])
        .batcher(BatcherConfig::new(4, Duration::from_micros(500)))
        .tracing(true)
        .slo(SloConfig::new(60_000_000, 0.99))
        .journaling(32)
        .telemetry(SamplerConfig::manual())
        .register("a", a.clone())
        .register("b", b.clone())
        .build()
        .expect("valid registry");
    let sampler = registry.sampler().expect("telemetry enabled");
    for x in a.synth_inputs(4, 1) {
        registry
            .submit("a", x)
            .expect("routed")
            .wait_timeout(Duration::from_secs(30))
            .expect("answered");
    }
    for x in b.synth_inputs(4, 2) {
        registry
            .submit("b", x)
            .expect("routed")
            .wait_timeout(Duration::from_secs(30))
            .expect("answered");
    }
    quiesce(|| {
        registry.in_flight("a").expect("known") + registry.in_flight("b").expect("known")
    });
    sampler.tick();

    // The fleet-wide sample sums both tenants' counters.
    let tl = registry.timeline().expect("telemetry enabled");
    let s = tl.latest().expect("ticked");
    assert_eq!(s.answered_total, 8, "answered is summed across tenants");
    assert_eq!(s.in_flight, 0);
    assert_eq!(s.queue_depth, 0);
    assert_eq!(s.occupancy.len(), 2, "one lane per shared device");

    // Per-tenant SLO status under a generous objective.
    let slo = registry.slo_status("a").expect("known").expect("slo configured");
    assert_eq!(slo.total(), 4);
    assert_eq!(slo.compliance, 1.0);
    assert!(matches!(registry.slo_status("nope"), Err(ServeError::UnknownTenant { .. })));

    // Merged exposition: one TYPE header per family across tenants,
    // tenant labels on every per-tenant sample, fleet gauges appended.
    let text = registry.prometheus_text();
    let mut families = std::collections::BTreeSet::new();
    for line in text.lines() {
        if let Some(rest) = line.strip_prefix("# TYPE ") {
            let fam = rest.split(' ').next().unwrap_or("");
            assert!(families.insert(fam.to_string()), "family {fam} declared twice");
        }
    }
    assert!(text.contains("npe_requests_total{tenant=\"a\"} 4"));
    assert!(text.contains("npe_requests_total{tenant=\"b\"} 4"));
    assert!(text.contains("npe_slo_compliance{tenant=\"a\"} 1.000000"));
    assert!(text.contains("npe_queue_depth 0"));
    assert!(text.contains("npe_in_flight 0"));
    assert!(text.contains("npe_device_occupancy{device=\"0\"}"));
    assert!(text.contains("npe_device_occupancy{device=\"1\"}"));

    // The timeline JSON round-trips through the in-repo parser and
    // advertises the fingerprint the snapshot computes.
    let tj = registry.timeline_json().expect("telemetry enabled");
    let doc = JsonValue::parse(&tj).expect("timeline JSON parses");
    let samples = doc.get("samples").and_then(JsonValue::as_arr).expect("samples array");
    assert_eq!(samples.len(), 1);
    assert_eq!(samples[0].get("answered_total").and_then(JsonValue::as_u64), Some(8));
    assert_eq!(
        doc.get("fingerprint").and_then(JsonValue::as_u64),
        Some(tl.fingerprint()),
        "exported fingerprint matches the snapshot's"
    );

    // With tracing + telemetry both on, the Chrome export carries the
    // timeline as counter tracks next to the span tracks.
    let trace = registry.trace_json();
    assert!(trace.contains("npe load"), "counter track exported");
    registry.shutdown().expect("clean shutdown");
}

#[test]
fn background_sampler_feeds_service_prometheus_gauges() {
    let model = mlp(0xB6);
    let service = NpeService::builder(model.clone())
        .devices(vec![NpeGeometry::PAPER; 2])
        .batcher(BatcherConfig::new(8, Duration::from_micros(200)))
        .telemetry(SamplerConfig::default().with_period(Duration::from_millis(5)))
        .build()
        .expect("valid service");
    for x in model.synth_inputs(16, 0xDA7A) {
        service
            .submit(x)
            .expect("admitted")
            .wait_timeout(Duration::from_secs(30))
            .expect("answered");
    }
    let sampler = service.sampler().expect("telemetry enabled");
    let deadline = Instant::now() + Duration::from_secs(5);
    while sampler.ticks() < 2 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(sampler.ticks() >= 2, "background thread ticks on its own");
    let text = service.metrics_snapshot().prometheus_text();
    assert!(text.contains("npe_queue_depth"), "gauges reach the service exposition");
    assert!(text.contains("npe_device_occupancy{device=\"0\"}"));
    service.shutdown().expect("clean shutdown");
}
