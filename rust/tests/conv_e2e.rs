//! End-to-end validation of the conv subsystem: CNN zoo models lowered
//! via im2col, scheduled by Algorithm 1, executed on the cycle-accurate
//! NPE, and compared bit-exactly against the `Fix16` reference GEMM path.

use std::time::Duration;
use tcd_npe::conv::{
    im2col, lower_cnn, CnnEngine, CnnLayer, CnnTopology, Conv2dLayer, Pool2dLayer, PoolKind,
    QuantizedCnn, TensorShape,
};
use tcd_npe::coordinator::BatcherConfig;
use tcd_npe::serve::NpeService;
use tcd_npe::mapper::{MapperTree, NpeGeometry};
use tcd_npe::model::zoo::{cnn_benchmark_by_name, cnn_benchmarks};
use tcd_npe::model::quantize_acc;

fn tiny_cnn(seed: u64) -> QuantizedCnn {
    QuantizedCnn::synthesize(
        CnnTopology::new(
            TensorShape::new(2, 7, 7),
            vec![
                CnnLayer::Conv(Conv2dLayer::square(2, 4, 3, 1)),
                CnnLayer::Pool(Pool2dLayer::square(PoolKind::Max, 2)),
                CnnLayer::Conv(Conv2dLayer::square(4, 6, 3, 0)),
                CnnLayer::Dense { out: 8 },
                CnnLayer::Dense { out: 3 },
            ],
        ),
        seed,
    )
}

#[test]
fn lenet5_executes_bit_exactly_on_the_npe() {
    // The acceptance run: LeNet-5, im2col-lowered, scheduled and executed
    // on the cycle-accurate NPE — output must equal the Fix16 reference
    // GEMM path bit-for-bit.
    let lenet = cnn_benchmark_by_name("lenet-5").unwrap();
    let cnn = QuantizedCnn::synthesize(lenet.topology.clone(), 0x1E9E7);
    let inputs = cnn.synth_inputs(2, 0xDA7A);
    let expect = cnn.forward_batch(&inputs);
    let report = CnnEngine::tcd(NpeGeometry::PAPER).execute(&cnn, &inputs);
    assert_eq!(report.outputs, expect, "NPE output == Fix16 reference");
    assert_eq!(report.outputs.len(), 2);
    assert_eq!(report.outputs[0].len(), 10);
    assert!(report.cycles > 0 && report.energy.total_pj() > 0.0);
}

#[test]
fn whole_cnn_zoo_matches_reference_on_both_mac_kinds() {
    for bench in cnn_benchmarks() {
        let cnn = QuantizedCnn::synthesize(bench.topology.clone(), 7);
        let inputs = cnn.synth_inputs(1, 5);
        let expect = cnn.forward_batch(&inputs);
        let tcd = CnnEngine::tcd(NpeGeometry::PAPER).execute(&cnn, &inputs);
        let conv = CnnEngine::conventional(NpeGeometry::PAPER).execute(&cnn, &inputs);
        assert_eq!(tcd.outputs, expect, "{}", bench.network);
        assert_eq!(conv.outputs, expect, "{}", bench.network);
        assert!(tcd.time_ns < conv.time_ns, "{}: TCD must be faster", bench.network);
    }
}

#[test]
fn geometry_independence() {
    // Values must not depend on the PE-array geometry, only the schedule.
    let cnn = tiny_cnn(11);
    let inputs = cnn.synth_inputs(3, 17);
    let expect = cnn.forward_batch(&inputs);
    for geom in [
        NpeGeometry::WALKTHROUGH,
        NpeGeometry::PAPER,
        NpeGeometry::new(4, 4),
        NpeGeometry::new(1, 3),
    ] {
        let report = CnnEngine::tcd(geom).execute(&cnn, &inputs);
        assert_eq!(report.outputs, expect, "{geom:?}");
    }
}

#[test]
fn bitexact_mac_models_agree_with_fast_path() {
    // The gate-level MAC planes must produce the same CNN outputs as the
    // 64-bit fast path (small net: the bit-exact path is slow).
    let cnn = QuantizedCnn::synthesize(
        CnnTopology::new(
            TensorShape::new(1, 5, 5),
            vec![
                CnnLayer::Conv(Conv2dLayer::square(1, 2, 3, 0)),
                CnnLayer::Dense { out: 3 },
            ],
        ),
        23,
    );
    let inputs = cnn.synth_inputs(2, 29);
    let fast = CnnEngine::tcd(NpeGeometry::WALKTHROUGH).execute(&cnn, &inputs);
    let slow = CnnEngine::tcd(NpeGeometry::WALKTHROUGH)
        .bitexact(true)
        .execute(&cnn, &inputs);
    assert_eq!(fast.outputs, slow.outputs);
    assert_eq!(fast.cycles, slow.cycles);
}

#[test]
fn lowered_schedules_cover_exactly_and_chain() {
    // conv → pool → dense lowering produces one coverage-exact Γ schedule
    // per parametric layer, chained into a single ModelSchedule.
    let lenet = cnn_benchmark_by_name("lenet-5").unwrap();
    let mut mapper = MapperTree::new(NpeGeometry::PAPER);
    let lowered = lower_cnn(&mut mapper, &lenet.topology, 3);
    assert_eq!(lowered.layers.len(), 5, "2 conv + 3 fc");
    // conv1 lowers to Γ(3·784, 25, 6); conv2 to Γ(3·100, 150, 16).
    assert_eq!(lowered.layers[0].gamma.batches, 3 * 784);
    assert_eq!(lowered.layers[0].gamma.inputs, 25);
    assert_eq!(lowered.layers[0].gamma.neurons, 6);
    assert_eq!(lowered.layers[1].gamma.batches, 3 * 100);
    assert_eq!(lowered.layers[1].gamma.inputs, 150);
    assert_eq!(lowered.layers[1].gamma.neurons, 16);
    for l in &lowered.layers {
        assert!(l.schedule.covers_exactly(), "{}", l.label);
    }
    let ms = lowered.model_schedule();
    assert_eq!(ms.total_rolls(), lowered.total_rolls());
    assert!(ms.utilization() > 0.0 && ms.utilization() <= 1.0);
}

#[test]
fn im2col_gemm_equals_direct_convolution() {
    // The lowering identity itself: patch · kernel-row dot products equal
    // the reference convolution for a conv-only network.
    let topo = CnnTopology::new(
        TensorShape::new(3, 6, 6),
        vec![CnnLayer::Conv(Conv2dLayer::new(3, 5, (3, 3), (2, 2), (1, 1)))],
    );
    let cnn = QuantizedCnn::synthesize(topo, 31);
    let input = &cnn.synth_inputs(1, 37)[0];
    let expect = cnn.forward_sample(input);

    let conv = match cnn.topology.layers[0] {
        CnnLayer::Conv(c) => c,
        _ => unreachable!(),
    };
    let rows = im2col(input, cnn.topology.input, &conv);
    let out = conv.out_shape(cnn.topology.input);
    let patch_len = conv.patch_len();
    let mut gemm = vec![0i16; out.features()];
    for (p, row) in rows.iter().enumerate() {
        for oc in 0..conv.out_channels {
            let wrow = &cnn.weights[0][oc * patch_len..(oc + 1) * patch_len];
            let acc: i64 = wrow
                .iter()
                .zip(row)
                .map(|(w, v)| (*w as i32 * *v as i32) as i64)
                .sum();
            gemm[oc * out.h * out.w + p] = quantize_acc(acc);
        }
    }
    assert_eq!(gemm, expect);
}

/// im2col edge cases, each cross-checked bit-exactly against the
/// nested-loop reference: the lowered GEMM must agree with direct
/// convolution index math even where the patch extraction is
/// irregular.
#[test]
fn im2col_edge_cases_match_nested_loop_reference() {
    // (label, input shape, conv): asymmetric kernels, stride > kernel
    // (windows skip input pixels entirely), and padding = kernel - 1
    // (every border patch is mostly zeros).
    let cases: Vec<(&str, TensorShape, Conv2dLayer)> = vec![
        (
            "asymmetric 3x2 kernel",
            TensorShape::new(2, 7, 6),
            Conv2dLayer::new(2, 3, (3, 2), (1, 1), (0, 0)),
        ),
        (
            "asymmetric 1x4 kernel with asymmetric padding",
            TensorShape::new(1, 5, 9),
            Conv2dLayer::new(1, 2, (1, 4), (1, 1), (0, 3)),
        ),
        (
            "stride 3 > kernel 2",
            TensorShape::new(1, 8, 8),
            Conv2dLayer::new(1, 4, (2, 2), (3, 3), (0, 0)),
        ),
        (
            "asymmetric stride (3,2) > kernel (2,1)",
            TensorShape::new(2, 9, 7),
            Conv2dLayer::new(2, 2, (2, 1), (3, 2), (0, 0)),
        ),
        (
            "padding = kernel - 1",
            TensorShape::new(1, 5, 5),
            Conv2dLayer::new(1, 3, (3, 3), (1, 1), (2, 2)),
        ),
        (
            "asymmetric kernel with padding = kernel - 1 and stride 2",
            TensorShape::new(2, 6, 4),
            Conv2dLayer::new(2, 3, (3, 2), (2, 2), (2, 1)),
        ),
    ];
    for (label, shape, conv) in cases {
        let topo = CnnTopology::new(
            shape,
            vec![CnnLayer::Conv(conv), CnnLayer::Dense { out: 3 }],
        );
        let cnn = QuantizedCnn::synthesize(topo, 0xED6E ^ shape.features() as u64);
        let inputs = cnn.synth_inputs(2, 0x5EED);
        let expect = cnn.forward_batch(&inputs);

        // The full NPE path (im2col -> Algorithm 1 -> PE array).
        let report = CnnEngine::tcd(NpeGeometry::PAPER).execute(&cnn, &inputs);
        assert_eq!(report.outputs, expect, "{label}: engine == reference");

        // And the bare lowering identity: patch . kernel-row == conv sum.
        let rows = im2col(&inputs[0], shape, &conv);
        let out = conv.out_shape(shape);
        assert_eq!(rows.len(), out.h * out.w, "{label}: patch count");
        assert!(
            rows.iter().all(|r| r.len() == conv.patch_len()),
            "{label}: patch length"
        );
    }
}

#[test]
fn coordinator_serves_lenet_traffic() {
    // CNN model handles flow through the batcher/router end to end.
    let lenet = cnn_benchmark_by_name("lenet-5").unwrap();
    let cnn = QuantizedCnn::synthesize(lenet.topology.clone(), 41);
    let inputs = cnn.synth_inputs(4, 43);
    let expect = cnn.forward_batch(&inputs);
    let service = NpeService::builder(cnn)
        .geometry(NpeGeometry::PAPER)
        .batcher(BatcherConfig { batch_size: 4, max_wait: Duration::from_millis(50) })
        .build()
        .unwrap();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| service.submit(x.clone()).expect("admitted"))
        .collect();
    for (t, want) in tickets.into_iter().zip(expect) {
        let resp = t.wait_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.output, want);
        assert!(resp.npe_energy_pj > 0.0);
    }
    service.shutdown().unwrap();
}
