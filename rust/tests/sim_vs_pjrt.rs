//! The stack-composition proof: the cycle-accurate NPE simulator (whose
//! MACs are bit-level carry-save models) and the PJRT-executed HLO lowered
//! from the JAX/Pallas kernel must agree **bit for bit** on every Table-IV
//! benchmark.
//!
//! Requires `make artifacts` (skips with a message when absent, so plain
//! `cargo test` works before the Python step).

use tcd_npe::coordinator::{BatcherConfig, Coordinator, PjrtSpec};
use tcd_npe::dataflow::{DataflowEngine, OsEngine};
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::QuantizedMlp;
use tcd_npe::runtime::{ArtifactManifest, PjrtRuntime};
use std::time::Duration;

fn manifest() -> Option<ArtifactManifest> {
    match ArtifactManifest::load("artifacts") {
        Ok(m) => Some(m),
        Err(_) => {
            eprintln!("artifacts/ missing — run `make artifacts`; skipping PJRT tests");
            None
        }
    }
}

#[test]
fn all_artifacts_bit_exact_vs_simulator() {
    let Some(manifest) = manifest() else { return };
    let mut rt = PjrtRuntime::new("artifacts").expect("PJRT CPU client");
    for e in &manifest.entries {
        rt.load(&e.name, e.batch).unwrap_or_else(|err| panic!("{}: {err}", e.name));
        let mlp = QuantizedMlp::synthesize(e.topology.clone(), e.seed);
        let inputs = mlp.synth_inputs(e.batch, e.seed ^ 0xDA7A);
        let sim = OsEngine::tcd(NpeGeometry::PAPER).execute(&mlp, &inputs);
        let pjrt = rt.execute(&e.name, &mlp, &inputs).expect("execute");
        assert_eq!(sim.outputs, pjrt, "{}", e.name);
        // And both equal the plain reference forward pass.
        assert_eq!(pjrt, mlp.forward_batch(&inputs), "{} vs reference", e.name);
    }
}

#[test]
fn pjrt_rejects_wrong_batch() {
    let Some(manifest) = manifest() else { return };
    let e = &manifest.entries[0];
    let mut rt = PjrtRuntime::new("artifacts").unwrap();
    rt.load(&e.name, e.batch).unwrap();
    let mlp = QuantizedMlp::synthesize(e.topology.clone(), e.seed);
    let inputs = mlp.synth_inputs(e.batch + 1, 1);
    assert!(rt.execute(&e.name, &mlp, &inputs).is_err());
}

#[test]
fn coordinator_cross_verifies_batches_end_to_end() {
    let Some(manifest) = manifest() else { return };
    // Iris is the cheapest artifact.
    let e = manifest
        .entries
        .iter()
        .find(|e| e.name.starts_with("iris"))
        .expect("iris artifact");
    let mlp = QuantizedMlp::synthesize(e.topology.clone(), e.seed);
    let coord = Coordinator::spawn(
        mlp.clone(),
        NpeGeometry::PAPER,
        BatcherConfig::new(e.batch, Duration::from_millis(20)),
        Some(PjrtSpec {
            artifact_dir: "artifacts".into(),
            artifact: e.name.clone(),
        }),
    );
    let inputs = mlp.synth_inputs(e.batch, 0x5EED);
    let expect = mlp.forward_batch(&inputs);
    let rxs: Vec<_> = inputs.iter().map(|x| coord.submit(x.clone())).collect();
    for (rx, want) in rxs.into_iter().zip(expect) {
        let resp = rx.recv_timeout(Duration::from_secs(60)).expect("response");
        assert_eq!(resp.output, want);
        assert!(resp.verified, "batch must be PJRT-verified");
    }
    let m = coord.metrics.lock().unwrap().clone();
    assert!(m.verified_batches >= 1);
    drop(m);
    coord.shutdown().unwrap();
}
