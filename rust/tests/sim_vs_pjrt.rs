//! The stack-composition proof: the cycle-accurate NPE simulator (whose
//! MACs are bit-level carry-save models) and the PJRT-executed HLO lowered
//! from the JAX/Pallas kernel must agree **bit for bit** on every Table-IV
//! benchmark.
//!
//! Requires `make artifacts`. When the artifacts are absent each test
//! skips **loudly** — an explicit `SKIPPED <test>: …` line naming the
//! probed directory and the reason — never via a silent early-return
//! that reads as green. Setting `TCD_NPE_REQUIRE_ARTIFACTS=1` (the
//! post-`make artifacts` CI configuration) turns the skip into a hard
//! failure, and [`missing_manifest_probes_loud_not_green`] guards the
//! probe itself so a typo'd directory can't masquerade as a pass.

use std::time::Duration;
use tcd_npe::coordinator::{BatcherConfig, PjrtSpec};
use tcd_npe::serve::NpeService;
use tcd_npe::dataflow::{DataflowEngine, OsEngine};
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::QuantizedMlp;
use tcd_npe::runtime::{ArtifactManifest, ArtifactStatus, PjrtRuntime};

/// The one directory `make artifacts` writes (guard-tested below).
const ARTIFACT_DIR: &str = "artifacts";

/// Resolve the PJRT artifacts, or skip this test with an explicit
/// report. `None` is only ever returned after the skip line has been
/// printed — and never when `TCD_NPE_REQUIRE_ARTIFACTS` demands the
/// artifacts exist.
fn manifest_or_skip(test: &str) -> Option<ArtifactManifest> {
    match ArtifactManifest::probe(ARTIFACT_DIR) {
        ArtifactStatus::Present(m) => Some(m),
        ArtifactStatus::Missing { dir, reason } => {
            // Honored by value, matching the documented `=1` contract:
            // unset, empty, or `0` means "skip loudly", anything else
            // means "artifacts are required — fail".
            let required = std::env::var("TCD_NPE_REQUIRE_ARTIFACTS")
                .map(|v| !v.is_empty() && v != "0")
                .unwrap_or(false);
            assert!(
                !required,
                "{test}: PJRT artifacts required but unavailable at {dir:?}: {reason}"
            );
            eprintln!(
                "SKIPPED {test}: PJRT artifacts unavailable at {dir:?} ({reason}); \
                 run `make artifacts`, or set TCD_NPE_REQUIRE_ARTIFACTS=1 to fail \
                 instead of skipping"
            );
            None
        }
    }
}

/// Guard for the skip path itself: probing a typo'd directory must
/// surface as an explicit `Missing` whose reason names the manifest it
/// wanted — the failure mode where a misspelled constant silently turns
/// the whole suite green is structurally impossible as long as this
/// holds (and as long as the suite probes the canonical directory,
/// asserted last).
#[test]
fn missing_manifest_probes_loud_not_green() {
    match ArtifactManifest::probe("artifacts-typo-guard-no-such-dir") {
        ArtifactStatus::Present(_) => panic!("a typo'd dir can never probe Present"),
        ArtifactStatus::Missing { dir, reason } => {
            assert!(dir.to_string_lossy().contains("artifacts-typo-guard-no-such-dir"));
            assert!(
                reason.contains("manifest.txt"),
                "skip reason must name the missing manifest: {reason}"
            );
            assert!(
                reason.contains("make artifacts"),
                "skip reason must say how to fix it: {reason}"
            );
        }
    }
    assert_eq!(
        ARTIFACT_DIR, "artifacts",
        "suite must probe the directory `make artifacts` writes"
    );
}

#[test]
fn all_artifacts_bit_exact_vs_simulator() {
    let Some(manifest) = manifest_or_skip("all_artifacts_bit_exact_vs_simulator") else {
        return;
    };
    let mut rt = PjrtRuntime::new(ARTIFACT_DIR).expect("PJRT CPU client");
    for e in &manifest.entries {
        rt.load(&e.name, e.batch).unwrap_or_else(|err| panic!("{}: {err}", e.name));
        let mlp = QuantizedMlp::synthesize(e.topology.clone(), e.seed);
        let inputs = mlp.synth_inputs(e.batch, e.seed ^ 0xDA7A);
        let sim = OsEngine::tcd(NpeGeometry::PAPER).execute(&mlp, &inputs);
        let pjrt = rt.execute(&e.name, &mlp, &inputs).expect("execute");
        assert_eq!(sim.outputs, pjrt, "{}", e.name);
        // And both equal the plain reference forward pass.
        assert_eq!(pjrt, mlp.forward_batch(&inputs), "{} vs reference", e.name);
    }
}

#[test]
fn pjrt_rejects_wrong_batch() {
    let Some(manifest) = manifest_or_skip("pjrt_rejects_wrong_batch") else {
        return;
    };
    let e = &manifest.entries[0];
    let mut rt = PjrtRuntime::new(ARTIFACT_DIR).unwrap();
    rt.load(&e.name, e.batch).unwrap();
    let mlp = QuantizedMlp::synthesize(e.topology.clone(), e.seed);
    let inputs = mlp.synth_inputs(e.batch + 1, 1);
    assert!(rt.execute(&e.name, &mlp, &inputs).is_err());
}

#[test]
fn coordinator_cross_verifies_batches_end_to_end() {
    let Some(manifest) = manifest_or_skip("coordinator_cross_verifies_batches_end_to_end")
    else {
        return;
    };
    // Iris is the cheapest artifact.
    let e = manifest
        .entries
        .iter()
        .find(|e| e.name.starts_with("iris"))
        .expect("iris artifact");
    let mlp = QuantizedMlp::synthesize(e.topology.clone(), e.seed);
    let service = NpeService::builder(mlp.clone())
        .geometry(NpeGeometry::PAPER)
        .batcher(BatcherConfig::new(e.batch, Duration::from_millis(20)))
        .pjrt(PjrtSpec {
            artifact_dir: ARTIFACT_DIR.into(),
            artifact: e.name.clone(),
        })
        .build()
        .unwrap();
    let inputs = mlp.synth_inputs(e.batch, 0x5EED);
    let expect = mlp.forward_batch(&inputs);
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| service.submit(x.clone()).expect("admitted"))
        .collect();
    for (t, want) in tickets.into_iter().zip(expect) {
        let resp = t.wait_timeout(Duration::from_secs(60)).expect("response");
        assert_eq!(resp.output, want);
        assert!(resp.verified, "batch must be PJRT-verified");
    }
    let m = service.metrics();
    assert!(m.verified_batches >= 1);
    assert_eq!(m.verify_mismatches, 0, "simulator and PJRT agree");
    service.shutdown().unwrap();
}
