//! Fleet end-to-end: determinism, bit-exact equivalence with the
//! single-NPE coordinator across the full MLP + CNN zoo, exactly-once
//! delivery through shutdown-with-queued-work, and the schedule-cache
//! correctness property.

use std::time::Duration;
use tcd_npe::conv::QuantizedCnn;
use tcd_npe::coordinator::{BatcherConfig, ServedModel};
use tcd_npe::fleet::{poisson_arrivals, run_open_loop, Arrival, LoadGenConfig};
use tcd_npe::mapper::{Gamma, MapperTree, NpeGeometry, ScheduleCache};
use tcd_npe::model::{benchmarks, cnn_benchmarks, QuantizedMlp};
use tcd_npe::serve::NpeService;

/// A heterogeneous 4-device fleet: responses must be bit-exact no
/// matter which geometry executes the batch.
fn four_geometries() -> Vec<NpeGeometry> {
    vec![
        NpeGeometry::PAPER,
        NpeGeometry::PAPER,
        NpeGeometry::WALKTHROUGH,
        NpeGeometry::new(8, 4),
    ]
}

fn batcher() -> BatcherConfig {
    BatcherConfig::new(2, Duration::from_millis(2))
}

/// Drive the stream and unwrap every response (panics on any loss).
fn serve_stream(service: &NpeService, arrivals: &[Arrival]) -> Vec<Vec<i16>> {
    run_open_loop(service, arrivals, Duration::from_secs(120))
        .into_iter()
        .enumerate()
        .map(|(i, o)| o.unwrap_or_else(|| panic!("request {i} lost")))
        .collect()
}

#[test]
fn fleet_matches_single_coordinator_on_full_mlp_zoo() {
    for (idx, b) in benchmarks().into_iter().enumerate() {
        let mlp = QuantizedMlp::synthesize(b.topology.clone(), 0x200_u64 + idx as u64);
        let model = ServedModel::Mlp(mlp.clone());
        let load = LoadGenConfig {
            seed: 0xE2E0 + idx as u64,
            rate_rps: 1e8,
            requests: 5,
        };
        let arrivals = poisson_arrivals(&model, &load);
        let expect: Vec<Vec<i16>> =
            arrivals.iter().map(|a| mlp.forward_sample(&a.input)).collect();

        // The single-NPE service path.
        let single = NpeService::builder(mlp.clone())
            .geometry(NpeGeometry::PAPER)
            .batcher(batcher())
            .build()
            .unwrap();
        let got_single = serve_stream(&single, &arrivals);
        single.shutdown().unwrap();

        // fleet(1): must match the single service bit-exactly.
        let fleet1 = NpeService::builder(mlp.clone())
            .devices([NpeGeometry::PAPER])
            .batcher(batcher())
            .build()
            .unwrap();
        let got_fleet1 = serve_stream(&fleet1, &arrivals);
        fleet1.shutdown().unwrap();

        // fleet(4), heterogeneous geometries.
        let fleet4 = NpeService::builder(mlp.clone())
            .devices(four_geometries())
            .batcher(batcher())
            .build()
            .unwrap();
        let got_fleet4 = serve_stream(&fleet4, &arrivals);
        fleet4.shutdown().unwrap();

        assert_eq!(got_single, expect, "{}: single == reference", b.dataset);
        assert_eq!(got_fleet1, expect, "{}: fleet(1) == single", b.dataset);
        assert_eq!(got_fleet4, expect, "{}: fleet(4) == single", b.dataset);
    }
}

#[test]
fn fleet_matches_single_coordinator_on_cnn_zoo() {
    for (idx, b) in cnn_benchmarks().into_iter().enumerate() {
        let cnn = QuantizedCnn::synthesize(b.topology.clone(), 0x300_u64 + idx as u64);
        let model = ServedModel::Cnn(cnn.clone());
        let load = LoadGenConfig {
            seed: 0xC2E0 + idx as u64,
            rate_rps: 1e8,
            requests: 4,
        };
        let arrivals = poisson_arrivals(&model, &load);
        let expect: Vec<Vec<i16>> =
            arrivals.iter().map(|a| cnn.forward_sample(&a.input)).collect();

        let single = NpeService::builder(cnn.clone())
            .geometry(NpeGeometry::PAPER)
            .batcher(batcher())
            .build()
            .unwrap();
        let got_single = serve_stream(&single, &arrivals);
        single.shutdown().unwrap();

        let fleet4 = NpeService::builder(cnn.clone())
            .devices(four_geometries())
            .batcher(batcher())
            .build()
            .unwrap();
        let got_fleet4 = serve_stream(&fleet4, &arrivals);
        fleet4.shutdown().unwrap();

        assert_eq!(got_single, expect, "{}: single == reference", b.network);
        assert_eq!(got_fleet4, expect, "{}: fleet(4) == single", b.network);
    }
}

#[test]
fn same_seeded_stream_is_deterministic_across_fleet_runs() {
    let b = benchmarks().into_iter().find(|b| b.dataset == "Wine").unwrap();
    let mlp = QuantizedMlp::synthesize(b.topology, 0xD0_0D);
    let load = LoadGenConfig { seed: 0x5EED, rate_rps: 1e7, requests: 24 };
    let arrivals = poisson_arrivals(&ServedModel::Mlp(mlp.clone()), &load);
    // Regenerating the stream must give byte-identical arrivals...
    let again = poisson_arrivals(&ServedModel::Mlp(mlp.clone()), &load);
    for (a, b) in arrivals.iter().zip(&again) {
        assert_eq!(a.at_ns, b.at_ns);
        assert_eq!(a.input, b.input);
    }
    // ...and two independent 4-device fleets must answer it identically,
    // regardless of how the batches landed on devices.
    let run = |arrivals: &[Arrival]| {
        let service = NpeService::builder(mlp.clone())
            .devices(four_geometries())
            .batcher(BatcherConfig::new(4, Duration::from_millis(1)))
            .build()
            .unwrap();
        let out = serve_stream(&service, arrivals);
        service.shutdown().unwrap();
        out
    };
    assert_eq!(run(&arrivals), run(&again));
}

#[test]
fn shutdown_with_queued_work_answers_every_request_exactly_once() {
    // Long max_wait + small fills: most of the 50 requests are still in
    // the batcher (or the fleet queue) when shutdown lands. None may be
    // lost, none answered twice — including across the fleet drain.
    let b = benchmarks().into_iter().find(|b| b.dataset == "Iris").unwrap();
    let mlp = QuantizedMlp::synthesize(b.topology, 0xF10C);
    let inputs = mlp.synth_inputs(50, 0x10AD);
    let expect = mlp.forward_batch(&inputs);
    let service = NpeService::builder(mlp.clone())
        .devices(four_geometries())
        .batcher(BatcherConfig::new(8, Duration::from_secs(30)))
        .build()
        .unwrap();
    let client = service.client();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| client.submit(x.clone()).expect("admitted"))
        .collect();
    let metrics = service.metrics_handle();
    service.shutdown().unwrap();

    for (i, (t, want)) in tickets.into_iter().zip(expect).enumerate() {
        let resp = t
            .wait_timeout(Duration::from_secs(5))
            .unwrap_or_else(|_| panic!("request {i} lost in shutdown"));
        assert_eq!(resp.output, want, "request {i} answered with wrong batch row");
        assert!(
            t.wait_timeout(Duration::from_millis(20)).is_err(),
            "request {i} answered more than once"
        );
    }
    let m = metrics.lock().unwrap();
    assert_eq!(m.requests, 50, "all accepted requests dispatched");
    assert_eq!(m.latencies.count(), 50);
    assert_eq!(m.devices.iter().map(|d| d.requests).sum::<u64>(), 50);
}

#[test]
fn schedule_cache_equals_fresh_mapper_for_all_small_shapes() {
    // The satellite property: for every geometry ≤ 8×4 and every
    // Γ(B, I, U) with B, I, U ≤ 16, the cached schedule is
    // event-for-event equal to a freshly computed one, and the hit/miss
    // counters add up to the lookups issued.
    for rows in 1..=8usize {
        for cols in 1..=4usize {
            let geom = NpeGeometry::new(rows, cols);
            let cache = ScheduleCache::new();
            let mut cached_mapper = MapperTree::new(geom);
            let mut fresh = MapperTree::new(geom);
            let mut lookups = 0u64;
            for b in 1..=16usize {
                for i in 1..=16usize {
                    for u in 1..=16usize {
                        let gamma = Gamma::new(b, i, u);
                        let got = cache.get_or_compute(&mut cached_mapper, gamma);
                        let want = fresh.schedule_layer(gamma);
                        lookups += 1;
                        assert_eq!(
                            got.layer.events, want.events,
                            "{geom:?} Γ({b}, {i}, {u}): cached != fresh"
                        );
                        assert_eq!(got.layer.gamma, want.gamma);
                        assert_eq!(got.layer.geometry, geom);
                        assert!(got.layer.covers_exactly(), "{geom:?} Γ({b}, {i}, {u})");
                    }
                }
            }
            // Every (B, I, U) is a distinct key: all cold lookups miss.
            let cold = cache.stats();
            assert_eq!(cold.lookups(), lookups, "{geom:?}: counters add up");
            assert_eq!(cold.misses, lookups, "{geom:?}: distinct shapes all miss");
            assert_eq!(cold.hits, 0);
            assert_eq!(cache.entries() as u64, lookups);
            // The warm pass must hit on every single shape.
            for b in 1..=16usize {
                for i in 1..=16usize {
                    for u in 1..=16usize {
                        let _ = cache.get_or_compute(&mut cached_mapper, Gamma::new(b, i, u));
                    }
                }
            }
            let warm = cache.stats();
            assert_eq!(warm.misses, lookups, "{geom:?}: warm pass adds no misses");
            assert_eq!(warm.hits, lookups, "{geom:?}: warm pass hits everything");
            assert_eq!(warm.lookups(), 2 * lookups);
            assert!((warm.hit_rate() - 0.5).abs() < 1e-12);
        }
    }
}
