//! The serving-API redesign contract, end to end:
//!
//! * builder misconfiguration is a typed `InvalidConfig`, not a hang;
//! * shape mismatch is refused at submit, before queue admission;
//! * `Reject` admission returns `QueueFull` at 2×-depth pressure on a
//!   1-device fleet; `ShedOldest` bounds the backlog by shedding the
//!   oldest tickets;
//! * `wait_timeout` expiry is non-destructive; shutdown races resolve
//!   as `ShuttingDown`; hung-up clients are a counted metric;
//! * the `Reject` in-flight bound is *exact* under a many-thread
//!   submit hammer — the compare-exchange reservation admits precisely
//!   `max_depth`, never one more;
//! * the coordinator/fleet/serve request path carries zero
//!   `unwrap()` / `expect(` / `panic!` / `unreachable!` (grep-enforced
//!   below).

use std::time::Duration;
use tcd_npe::coordinator::BatcherConfig;
use tcd_npe::fleet::DeviceSpec;
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{MlpTopology, QuantizedMlp};
use tcd_npe::serve::{AdmissionPolicy, NpeService, ServeError};

fn mlp() -> QuantizedMlp {
    QuantizedMlp::synthesize(MlpTopology::new(vec![16, 12, 4]), 0x5E12)
}

fn batcher(batch: usize, wait: Duration) -> BatcherConfig {
    BatcherConfig { batch_size: batch, max_wait: wait }
}

// ---------------------------------------------------------------- builder

#[test]
fn builder_rejects_zero_batch_size() {
    let err = NpeService::builder(mlp())
        .batcher(batcher(0, Duration::from_millis(1)))
        .build()
        .err()
        .expect("zero batch size must not build");
    assert!(
        matches!(&err, ServeError::InvalidConfig { reason } if reason.contains("batch_size")),
        "{err:?}"
    );
}

#[test]
fn builder_rejects_zero_devices() {
    let err = NpeService::builder(mlp())
        .devices(Vec::<DeviceSpec>::new())
        .build()
        .err()
        .expect("zero devices must not build");
    assert!(
        matches!(&err, ServeError::InvalidConfig { reason } if reason.contains("device")),
        "{err:?}"
    );
}

#[test]
fn builder_rejects_zero_cache_and_zero_admission_depth() {
    assert!(matches!(
        NpeService::builder(mlp()).cache(0).build(),
        Err(ServeError::InvalidConfig { .. })
    ));
    assert!(matches!(
        NpeService::builder(mlp())
            .admission(AdmissionPolicy::Reject { max_depth: 0 })
            .build(),
        Err(ServeError::InvalidConfig { .. })
    ));
    assert!(matches!(
        NpeService::builder(mlp())
            .admission(AdmissionPolicy::ShedOldest { max_depth: 0 })
            .build(),
        Err(ServeError::InvalidConfig { .. })
    ));
}

// ------------------------------------------------------- submit-time checks

#[test]
fn shape_mismatch_is_refused_at_submit() {
    let svc = NpeService::builder(mlp())
        .geometry(NpeGeometry::WALKTHROUGH)
        .batcher(batcher(2, Duration::from_millis(5)))
        .build()
        .unwrap();
    let err = svc.submit(vec![1; 3]).expect_err("wrong length refused");
    assert_eq!(err, ServeError::ShapeMismatch { expected: 16, got: 3 });
    assert_eq!(svc.metrics().rejected_requests, 1, "refusal is observable");
    assert_eq!(svc.in_flight(), 0, "refused requests never occupy queue space");
    // Valid traffic keeps flowing.
    let m = mlp();
    let good = m.synth_inputs(1, 7)[0].clone();
    let expect = m.forward_batch(&[good.clone()]);
    let resp = svc.submit(good).expect("admitted").wait().expect("answered");
    assert_eq!(resp.output, expect[0]);
    svc.shutdown().unwrap();
}

#[test]
fn reject_admission_returns_queue_full_on_one_device_fleet() {
    // Long max_wait + big batch: the four admitted requests sit in the
    // batcher, so the in-flight depth deterministically stays at 4 when
    // the fifth submit arrives.
    let m = mlp();
    let svc = NpeService::builder(m.clone())
        .devices([NpeGeometry::PAPER])
        .batcher(batcher(64, Duration::from_secs(5)))
        .admission(AdmissionPolicy::Reject { max_depth: 4 })
        .build()
        .unwrap();
    let inputs = m.synth_inputs(6, 0xADA);
    let expect = m.forward_batch(&inputs);
    let mut tickets = Vec::new();
    for x in inputs.iter().take(4) {
        tickets.push(svc.submit(x.clone()).expect("under the bound"));
    }
    assert_eq!(svc.in_flight(), 4);
    for x in inputs.iter().skip(4) {
        match svc.submit(x.clone()) {
            Err(ServeError::QueueFull { depth, max_depth }) => {
                assert_eq!(max_depth, 4);
                assert!(depth >= 4, "observed depth {depth}");
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
    }
    assert_eq!(svc.metrics().shed_requests, 2, "both refusals counted");
    // The admitted four are still answered bit-exactly through shutdown.
    svc.shutdown().unwrap();
    for (t, want) in tickets.into_iter().zip(expect) {
        assert_eq!(t.wait_timeout(Duration::from_secs(5)).unwrap().output, want);
    }
}

#[test]
fn shed_oldest_bounds_the_backlog_and_sheds_the_oldest() {
    // batch 16 never fills; after the 300 ms flush deadline the loop
    // sees all six requests, sheds the four oldest down to the bound of
    // two, and answers the two newest.
    let m = mlp();
    let svc = NpeService::builder(m.clone())
        .geometry(NpeGeometry::WALKTHROUGH)
        .batcher(batcher(16, Duration::from_millis(300)))
        .admission(AdmissionPolicy::ShedOldest { max_depth: 2 })
        .build()
        .unwrap();
    let inputs = m.synth_inputs(6, 0x5EED);
    let expect = m.forward_batch(&inputs);
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| svc.submit(x.clone()).expect("ShedOldest admits everything"))
        .collect();
    let mut outcomes = Vec::new();
    for t in tickets {
        outcomes.push(t.wait_timeout(Duration::from_secs(10)));
    }
    for (i, o) in outcomes.iter().take(4).enumerate() {
        assert!(
            matches!(o, Err(ServeError::QueueFull { max_depth: 2, .. })),
            "oldest request {i} must be shed, got {o:?}"
        );
    }
    for (i, o) in outcomes.iter().enumerate().skip(4) {
        let resp = o.as_ref().unwrap_or_else(|e| panic!("newest request {i} lost: {e}"));
        assert_eq!(resp.output, expect[i], "newest requests answered bit-exactly");
    }
    assert_eq!(svc.metrics().shed_requests, 4);
    svc.shutdown().unwrap();
}

// ------------------------------------------------------------ ticket waits

#[test]
fn wait_timeout_expiry_is_typed_and_non_destructive() {
    let m = mlp();
    let svc = NpeService::builder(m.clone())
        .geometry(NpeGeometry::WALKTHROUGH)
        .batcher(batcher(64, Duration::from_secs(30)))
        .build()
        .unwrap();
    let input = m.synth_inputs(1, 3)[0].clone();
    let expect = m.forward_batch(&[input.clone()]);
    let ticket = svc.submit(input).expect("admitted");
    // The batch can't fill and the deadline is far away: expiry.
    match ticket.wait_timeout(Duration::from_millis(50)) {
        Err(ServeError::Timeout { waited }) => {
            // `waited` reports time actually elapsed, not the deadline
            // passed in — it can only run over, never under.
            assert!(
                waited >= Duration::from_millis(50),
                "waited {waited:?} < the 50 ms deadline"
            );
        }
        other => panic!("expected Timeout, got {other:?}"),
    }
    // The ticket survives the expiry: shutdown flushes and the same
    // ticket then yields the real response.
    svc.shutdown().unwrap();
    let resp = ticket.wait().expect("flushed on shutdown");
    assert_eq!(resp.output, expect[0]);
}

#[test]
fn submits_racing_shutdown_get_shutting_down() {
    let m = mlp();
    let svc = NpeService::builder(m.clone())
        .geometry(NpeGeometry::WALKTHROUGH)
        .batcher(batcher(4, Duration::from_millis(1)))
        .build()
        .unwrap();
    let client = svc.client();
    svc.shutdown().unwrap();
    for _ in 0..3 {
        assert_eq!(
            client.submit(m.synth_inputs(1, 1)[0].clone()).expect_err("service gone"),
            ServeError::ShuttingDown
        );
    }
}

#[test]
fn hung_up_client_is_a_counted_metric_not_a_crash() {
    // A batcher that can only flush at shutdown makes the race-free
    // order certain: the ticket is dropped while its request is still
    // queued, so the eventual response send must find a dead client.
    let m = mlp();
    let svc = NpeService::builder(m.clone())
        .geometry(NpeGeometry::WALKTHROUGH)
        .batcher(batcher(64, Duration::from_secs(30)))
        .build()
        .unwrap();
    let ticket = svc.submit(m.synth_inputs(1, 9)[0].clone()).expect("admitted");
    drop(ticket); // client gives up immediately
    let metrics = svc.metrics_handle();
    svc.shutdown().unwrap(); // the flush still executes the request
    let m = metrics.lock().unwrap().clone();
    assert_eq!(m.requests, 1, "request was executed");
    assert_eq!(m.responses_dropped, 1, "the dead client is observable");
}

#[test]
fn fleet_shed_oldest_never_loses_a_ticket() {
    // Flood a 1-device fleet under ShedOldest: every ticket must resolve
    // — answered or QueueFull — and the counts must partition the flood.
    let m = mlp();
    let svc = NpeService::builder(m.clone())
        .devices([NpeGeometry::WALKTHROUGH])
        .batcher(batcher(1, Duration::ZERO))
        .admission(AdmissionPolicy::ShedOldest { max_depth: 1 })
        .build()
        .unwrap();
    let inputs = m.synth_inputs(16, 0xF100D);
    let expect = m.forward_batch(&inputs);
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| svc.submit(x.clone()).expect("admits everything"))
        .collect();
    let mut answered = 0u64;
    let mut shed = 0u64;
    for (i, t) in tickets.into_iter().enumerate() {
        match t.wait_timeout(Duration::from_secs(30)) {
            Ok(resp) => {
                answered += 1;
                assert_eq!(resp.output, expect[i], "answered responses stay bit-exact");
            }
            Err(ServeError::QueueFull { .. }) => shed += 1,
            other => panic!("request {i}: unexpected outcome {other:?}"),
        }
    }
    assert_eq!(answered + shed, 16, "every ticket resolves exactly once");
    assert!(answered >= 1, "the newest work still gets served");
    let metrics = svc.metrics();
    assert_eq!(metrics.shed_requests, shed);
    svc.shutdown().unwrap();
}

// ------------------------------------------- admission race (the hammer)

/// The `Reject` bound is exact under contention. 32 threads hammer a
/// service whose batcher can only flush at shutdown (batch 64, 30 s
/// deadline), so nothing leaves the queue mid-test: the compare-exchange
/// reservation must admit *exactly* `max_depth` requests across all
/// threads, the sampler must never observe `in_flight() > max_depth`,
/// and every refusal must be a typed `QueueFull`. Before the fix, the
/// check-then-increment window admitted up to one extra request per
/// racing thread.
#[test]
fn reject_bound_is_exact_under_a_32_thread_hammer() {
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{Barrier, Mutex};

    const THREADS: usize = 32;
    const ATTEMPTS: usize = 8;
    const MAX_DEPTH: usize = 4;

    let m = mlp();
    let svc = NpeService::builder(m.clone())
        .geometry(NpeGeometry::WALKTHROUGH)
        .batcher(batcher(64, Duration::from_secs(30)))
        .admission(AdmissionPolicy::Reject { max_depth: MAX_DEPTH })
        .build()
        .unwrap();
    let input = m.synth_inputs(1, 0x4A44)[0].clone();
    let expect = m.forward_batch(&[input.clone()])[0].clone();

    let accepted = AtomicUsize::new(0);
    let refused = AtomicUsize::new(0);
    let overshoots = AtomicUsize::new(0);
    let stop = AtomicBool::new(false);
    let start = Barrier::new(THREADS + 1);
    let tickets = Mutex::new(Vec::new());
    std::thread::scope(|s| {
        // Continuous depth sampler, running for the whole hammer window.
        s.spawn(|| {
            while !stop.load(Ordering::Relaxed) {
                if svc.in_flight() > MAX_DEPTH {
                    overshoots.fetch_add(1, Ordering::Relaxed);
                }
                std::thread::yield_now();
            }
        });
        let submitters: Vec<_> = (0..THREADS)
            .map(|_| {
                s.spawn(|| {
                    start.wait();
                    for _ in 0..ATTEMPTS {
                        match svc.submit(input.clone()) {
                            Ok(t) => {
                                accepted.fetch_add(1, Ordering::Relaxed);
                                tickets.lock().unwrap().push(t);
                            }
                            Err(ServeError::QueueFull { depth, max_depth }) => {
                                assert_eq!(max_depth, MAX_DEPTH);
                                assert!(depth >= MAX_DEPTH, "refused below the bound at {depth}");
                                refused.fetch_add(1, Ordering::Relaxed);
                            }
                            other => panic!("unexpected submit outcome {other:?}"),
                        }
                    }
                })
            })
            .collect();
        start.wait();
        for h in submitters {
            h.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
    });

    assert_eq!(overshoots.load(Ordering::Relaxed), 0, "in_flight exceeded max_depth");
    assert_eq!(
        accepted.load(Ordering::Relaxed),
        MAX_DEPTH,
        "exactly max_depth admissions (nothing completes mid-hammer)"
    );
    assert_eq!(refused.load(Ordering::Relaxed), THREADS * ATTEMPTS - MAX_DEPTH);
    assert_eq!(svc.metrics().shed_requests as usize, THREADS * ATTEMPTS - MAX_DEPTH);
    // The admitted requests are real: shutdown flushes them bit-exactly.
    svc.shutdown().unwrap();
    for t in tickets.into_inner().unwrap() {
        assert_eq!(t.wait_timeout(Duration::from_secs(5)).unwrap().output, expect);
    }
}

// ------------------------------------------- panic-free request path (grep)

/// The redesign's hard promise: no `unwrap()` / `expect(` / `panic!` /
/// `unreachable!` / `todo!` anywhere on the coordinator/fleet/serve
/// request path — registry routing included. Test code (everything from
/// the first `#[cfg(test)]`) is exempt.
#[test]
fn request_path_carries_no_panics() {
    let root = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
    let files = [
        "coordinator/mod.rs",
        "coordinator/batcher.rs",
        "coordinator/metrics.rs",
        "fleet/mod.rs",
        "fleet/controller.rs",
        "fleet/device.rs",
        "fleet/queue.rs",
        "fleet/loadgen.rs",
        "serve/mod.rs",
        "serve/admission.rs",
        "serve/builder.rs",
        "serve/error.rs",
        "serve/registry.rs",
        "serve/service.rs",
        "serve/ticket.rs",
    ];
    let banned = [".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];
    let mut violations = Vec::new();
    for f in files {
        let path = root.join(f);
        let text = std::fs::read_to_string(&path)
            .unwrap_or_else(|e| panic!("request-path source {f} must exist: {e}"));
        // Strip the trailing test module (tests may unwrap freely).
        let body = text.split("#[cfg(test)]").next().unwrap_or("");
        for (lineno, line) in body.lines().enumerate() {
            let code = line.split("//").next().unwrap_or("");
            for b in banned {
                if code.contains(b) {
                    violations.push(format!("{f}:{}: {} — `{b}`", lineno + 1, line.trim()));
                }
            }
        }
    }
    assert!(
        violations.is_empty(),
        "panic paths found on the request path:\n{}",
        violations.join("\n")
    );
}
