//! Service/fleet stress: 32 concurrent client threads against a small
//! batcher through the `NpeService` facade — no deadlock (bounded wall
//! clock), monotonically consistent metrics, and wrong-length requests
//! refused at the submit gate yet still observable in the `rejected`
//! counter (regression guard for the PR-1 fix).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};
use tcd_npe::coordinator::BatcherConfig;
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{MlpTopology, QuantizedMlp};
use tcd_npe::serve::{NpeService, ServeError};

const CLIENTS: usize = 32;
const VALID_PER_CLIENT: usize = 12;
const INVALID_PER_CLIENT: usize = 4;
/// Generous no-deadlock bound for a debug-mode CI runner.
const WALL_BOUND: Duration = Duration::from_secs(120);

fn stress_mlp() -> QuantizedMlp {
    QuantizedMlp::synthesize(MlpTopology::new(vec![16, 12, 4]), 0x57E55)
}

/// Watch the metrics while the storm runs: every counter must be
/// non-decreasing and internally consistent in every snapshot.
fn start_monitor(
    service: &NpeService,
    done: Arc<AtomicBool>,
) -> std::thread::JoinHandle<u64> {
    let metrics = service.metrics_handle();
    let cache = service.cache();
    std::thread::spawn(move || {
        let mut last_requests = 0u64;
        let mut last_rejected = 0u64;
        let mut last_batches = 0u64;
        let mut last_latencies = 0u64;
        let mut last_lookups = 0u64;
        let mut snapshots = 0u64;
        while !done.load(Ordering::Relaxed) {
            let m = metrics.lock().unwrap().clone();
            assert!(m.requests >= last_requests, "requests went backwards");
            assert!(m.rejected_requests >= last_rejected, "rejected went backwards");
            assert!(m.batches >= last_batches, "batches went backwards");
            assert!(m.latencies.count() >= last_latencies, "latency count shrank");
            assert!(m.batches <= m.requests.max(1), "more batches than requests");
            assert!(
                m.latencies_recorded == m.requests,
                "one latency recorded per dispatched request (got {} for {})",
                m.latencies_recorded,
                m.requests
            );
            assert!(
                m.latencies.count() == m.requests,
                "the histogram holds every recorded latency (no sample cap)"
            );
            let occupancy = m.batch_occupancy();
            assert!((0.0..=1.0).contains(&occupancy), "occupancy {occupancy}");
            assert_eq!(
                m.devices.iter().map(|d| d.requests).sum::<u64>(),
                m.requests,
                "device lanes must partition the request count"
            );
            // Cache counters come from one shared-cache snapshot, so
            // they are monotone and internally consistent even while
            // many lanes race (regression guard for the
            // last-writer-wins overwrite this PR removed).
            let stats = cache.stats();
            assert_eq!(
                stats.hits + stats.misses,
                stats.lookups(),
                "cache snapshot is internally consistent"
            );
            assert!(stats.lookups() >= last_lookups, "cache lookups went backwards");
            last_lookups = stats.lookups();
            last_requests = m.requests;
            last_rejected = m.rejected_requests;
            last_batches = m.batches;
            last_latencies = m.latencies.count();
            snapshots += 1;
            std::thread::sleep(Duration::from_millis(1));
        }
        snapshots
    })
}

fn run_stress(service: NpeService, mlp: &QuantizedMlp) {
    let t0 = Instant::now();
    let done = Arc::new(AtomicBool::new(false));
    let monitor = start_monitor(&service, Arc::clone(&done));

    let workers: Vec<_> = (0..CLIENTS)
        .map(|c| {
            let client = service.client();
            let mlp = mlp.clone();
            std::thread::spawn(move || {
                let inputs = mlp.synth_inputs(VALID_PER_CLIENT, 0xC11E57 + c as u64);
                let expect = mlp.forward_batch(&inputs);
                let mut tickets = Vec::new();
                for (i, x) in inputs.iter().enumerate() {
                    tickets.push((client.submit(x.clone()).expect("valid request admitted"), i));
                    if i < INVALID_PER_CLIENT {
                        // Interleave malformed traffic (wrong length):
                        // refused at the submit gate with a typed error.
                        match client.submit(vec![7; 3]) {
                            Err(ServeError::ShapeMismatch { expected: 16, got: 3 }) => {}
                            other => panic!("malformed submit must be ShapeMismatch: {other:?}"),
                        }
                    }
                }
                for (t, i) in tickets {
                    let resp = t
                        .wait_timeout(Duration::from_secs(60))
                        .unwrap_or_else(|e| panic!("client {c} request {i}: {e}"));
                    assert_eq!(resp.output, expect[i], "client {c} request {i}");
                }
            })
        })
        .collect();
    for w in workers {
        w.join().expect("client thread panicked");
    }
    done.store(true, Ordering::Relaxed);
    let snapshots = monitor.join().expect("monitor panicked");
    assert!(snapshots > 0, "monitor observed at least one snapshot");

    assert!(
        t0.elapsed() < WALL_BOUND,
        "stress took {:?} — deadlock or pathological slowdown",
        t0.elapsed()
    );

    // Overlaid snapshot (cache counters included) before shutdown; the
    // raw handle stays valid for the post-shutdown counters.
    let overlaid = service.metrics();
    let metrics = service.metrics_handle();
    let cache = service.cache();
    service.shutdown().unwrap();
    let m = metrics.lock().unwrap().clone();
    assert_eq!(m.requests, (CLIENTS * VALID_PER_CLIENT) as u64, "no valid request lost");
    assert_eq!(
        m.rejected_requests,
        (CLIENTS * INVALID_PER_CLIENT) as u64,
        "every malformed request counted"
    );
    assert_eq!(m.latencies.count(), (CLIENTS * VALID_PER_CLIENT) as u64);
    assert!(m.batches >= 1);
    assert!(m.p99_us() >= m.p50_us());
    // The overlaid metrics snapshot of the cache counters matches the
    // cache itself (all traffic had drained before it was taken).
    let stats = cache.stats();
    assert_eq!(overlaid.cache_hits + overlaid.cache_misses, stats.lookups());
    assert!(stats.hits > stats.misses, "steady state is hit-dominated");
}

#[test]
fn stress_single_service_32_clients() {
    let mlp = stress_mlp();
    let service = NpeService::builder(mlp.clone())
        .geometry(NpeGeometry::WALKTHROUGH)
        .batcher(BatcherConfig::new(4, Duration::from_millis(1)))
        .build()
        .unwrap();
    run_stress(service, &mlp);
}

#[test]
fn stress_fleet_service_32_clients() {
    let mlp = stress_mlp();
    let service = NpeService::builder(mlp.clone())
        .devices([
            NpeGeometry::PAPER,
            NpeGeometry::WALKTHROUGH,
            NpeGeometry::new(8, 4),
            NpeGeometry::new(4, 4),
        ])
        .batcher(BatcherConfig::new(4, Duration::from_millis(1)))
        .build()
        .unwrap();
    run_stress(service, &mlp);
}
