//! Grep-enforced guard: the legacy coordinator shim layer is gone and
//! stays gone. No first-party Rust source — library, tests, criterion
//! benches, examples — may reference the retired shim entry points or
//! their module, and the module file itself must not exist.
//!
//! The banned substrings are assembled with `concat!` so this test's
//! own source never matches its own scan.

use std::path::{Path, PathBuf};

/// Every `.rs` file under `dir`, recursively.
fn rust_sources(dir: &Path, out: &mut Vec<PathBuf>) {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) => panic!("guard must be able to read {}: {e}", dir.display()),
    };
    for entry in entries {
        let path = entry.expect("readable dir entry").path();
        if path.is_dir() {
            rust_sources(&path, out);
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
}

#[test]
fn no_first_party_code_references_the_retired_shims() {
    let crate_root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let trees = [
        crate_root.join("src"),
        crate_root.join("tests"),
        crate_root.join("benches"),
        crate_root.join("../examples"),
    ];
    // The shim prefix (the seven retired Coordinator entry points;
    // bare std::thread::spawn carries no trailing underscore and stays
    // legal) and the deleted module's name.
    let banned = [concat!("sp", "awn_"), concat!("com", "pat")];

    let mut files = Vec::new();
    for tree in &trees {
        assert!(tree.is_dir(), "guarded tree {} must exist", tree.display());
        rust_sources(tree, &mut files);
    }
    assert!(files.len() > 20, "the walk found implausibly few sources");

    let mut violations = Vec::new();
    for path in &files {
        let text = std::fs::read_to_string(path)
            .unwrap_or_else(|e| panic!("guard must read {}: {e}", path.display()));
        for (lineno, line) in text.lines().enumerate() {
            for b in banned {
                if line.contains(b) {
                    violations.push(format!(
                        "{}:{}: `{b}` — {}",
                        path.display(),
                        lineno + 1,
                        line.trim()
                    ));
                }
            }
        }
    }
    assert!(
        violations.is_empty(),
        "retired shim references found:\n{}",
        violations.join("\n")
    );
}

#[test]
fn the_shim_module_file_is_gone() {
    let path =
        Path::new(env!("CARGO_MANIFEST_DIR")).join(format!("src/coordinator/{}.rs", concat!("com", "pat")));
    assert!(
        !path.exists(),
        "{} must stay deleted — the builder and registry are the only construction paths",
        path.display()
    );
}
