//! End-to-end validation of the graph compiler: the DAG zoo lowered by
//! the pass pipeline + sibling-sharing lowering, scheduled by Algorithm
//! 1, executed on the cycle-accurate NPE, served through both backends,
//! and compared bit-exactly against the nested-loop Fix16 reference
//! interpreter. The legacy sequential front-ends are checked to be
//! exactly re-expressed: `into_graph()` reproduces the OS/CNN engines'
//! outputs bit-for-bit.

use std::time::Duration;
use tcd_npe::conv::{CnnEngine, QuantizedCnn};
use tcd_npe::coordinator::BatcherConfig;
use tcd_npe::serve::NpeService;
use tcd_npe::dataflow::{DataflowEngine, OsEngine};
use tcd_npe::graph::{lower_graph, optimize, GraphEngine, QuantizedGraph};
use tcd_npe::mapper::{MapperTree, NpeGeometry};
use tcd_npe::model::zoo::{cnn_benchmark_by_name, graph_benchmarks};
use tcd_npe::model::{benchmark_by_name, QuantizedMlp};

const SEED: u64 = 0x6AF0_0D5;

#[test]
fn zoo_graphs_execute_bit_exactly_raw_and_optimized() {
    // Every DAG zoo entry, on the cycle-accurate NPE: the raw graph, the
    // optimized graph, and the unfused lowering must all equal the
    // nested-loop reference interpreter bit-for-bit.
    for b in graph_benchmarks() {
        let q = QuantizedGraph::synthesize(b.graph.clone(), SEED);
        let inputs = q.synth_inputs(3, 0xDA7A);
        let expect = q.forward_batch(&inputs);

        let raw = GraphEngine::tcd(NpeGeometry::PAPER).execute(&q, &inputs);
        assert_eq!(raw.outputs, expect, "{}: raw graph", b.network);

        let (opt, stats) = optimize(&q);
        assert!(stats.activations_folded > 0, "{}: folds something", b.network);
        let opted = GraphEngine::tcd(NpeGeometry::PAPER).execute(&opt, &inputs);
        assert_eq!(opted.outputs, expect, "{}: optimized graph", b.network);
        assert_eq!(opt.forward_batch(&inputs), expect, "{}: reference(opt)", b.network);

        let unfused = GraphEngine::tcd(NpeGeometry::PAPER)
            .fused(false)
            .execute(&q, &inputs);
        assert_eq!(unfused.outputs, expect, "{}: unfused lowering", b.network);
        assert!(raw.cycles > 0 && raw.energy.total_pj() > 0.0);
    }
}

#[test]
fn zoo_graphs_serve_bit_exactly_on_single_backend() {
    for b in graph_benchmarks() {
        let q = QuantizedGraph::synthesize(b.graph.clone(), SEED ^ 1);
        let inputs = q.synth_inputs(5, 0xBEE5);
        let expect = q.forward_batch(&inputs);
        let service = NpeService::builder(q)
            .geometry(NpeGeometry::PAPER)
            .batcher(BatcherConfig { batch_size: 3, max_wait: Duration::from_millis(20) })
            .build()
            .unwrap();
        let tickets: Vec<_> = inputs
            .iter()
            .map(|x| service.submit(x.clone()).expect("admitted"))
            .collect();
        for (t, want) in tickets.into_iter().zip(expect) {
            let resp = t.wait_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.output, want, "{}: served == reference", b.network);
            assert!(resp.npe_time_ns > 0.0);
        }
        let metrics = service.metrics();
        assert_eq!(metrics.requests, 5, "{}", b.network);
        assert!(metrics.cache_hits + metrics.cache_misses > 0, "{}", b.network);
        service.shutdown().unwrap();
    }
}

#[test]
fn zoo_graphs_serve_bit_exactly_on_fleet_backend() {
    // Heterogeneous fleet: responses must be identical regardless of
    // which device geometry executes a batch.
    for b in graph_benchmarks() {
        let q = QuantizedGraph::synthesize(b.graph.clone(), SEED ^ 2);
        let inputs = q.synth_inputs(8, 0xF1EE7);
        let expect = q.forward_batch(&inputs);
        let service = NpeService::builder(q)
            .devices([NpeGeometry::PAPER, NpeGeometry::WALKTHROUGH])
            .batcher(BatcherConfig { batch_size: 3, max_wait: Duration::from_millis(5) })
            .build()
            .unwrap();
        let client = service.client();
        let tickets: Vec<_> = inputs
            .iter()
            .map(|x| client.submit(x.clone()).expect("admitted"))
            .collect();
        for (t, want) in tickets.into_iter().zip(expect) {
            let resp = t.wait_timeout(Duration::from_secs(30)).unwrap();
            assert_eq!(resp.output, want, "{}: fleet == reference", b.network);
        }
        let metrics_handle = service.metrics_handle();
        service.shutdown().unwrap();
        let metrics = metrics_handle.lock().unwrap().clone();
        assert_eq!(metrics.requests, 8, "{}", b.network);
        assert_eq!(metrics.devices.len(), 2);
        assert_eq!(
            metrics.devices.iter().map(|d| d.requests).sum::<u64>(),
            8,
            "{}: lanes partition the requests",
            b.network
        );
    }
}

#[test]
fn mlp_into_graph_reproduces_legacy_engine_exactly() {
    // Table-IV topologies re-expressed through the graph path must match
    // the legacy OS engine bit-for-bit: same synthesized weights, same
    // served values.
    for name in ["Iris", "Wine"] {
        let bench = benchmark_by_name(name).unwrap();
        let mlp = QuantizedMlp::synthesize(bench.topology.clone(), SEED ^ 3);
        let q = QuantizedGraph::synthesize(bench.topology.clone().into_graph(), SEED ^ 3);
        assert_eq!(q.weights, mlp.weights, "{name}: identical weight streams");

        let inputs = mlp.synth_inputs(6, 0x1D1D);
        let legacy = OsEngine::tcd(NpeGeometry::PAPER).execute(&mlp, &inputs);
        let graph = GraphEngine::tcd(NpeGeometry::PAPER).execute(&q, &inputs);
        assert_eq!(graph.outputs, legacy.outputs, "{name}: graph == OS engine");
        assert_eq!(legacy.outputs, mlp.forward_batch(&inputs), "{name}: sanity");

        // The optimized graph (ReLUs folded) must not change a bit.
        let (opt, stats) = optimize(&q);
        assert_eq!(stats.activations_folded, bench.topology.layers.len() - 2);
        let opted = GraphEngine::tcd(NpeGeometry::PAPER).execute(&opt, &inputs);
        assert_eq!(opted.outputs, legacy.outputs, "{name}: optimized == legacy");
    }
}

#[test]
fn cnn_into_graph_reproduces_legacy_engine_exactly() {
    let lenet = cnn_benchmark_by_name("lenet-5").unwrap();
    let cnn = QuantizedCnn::synthesize(lenet.topology.clone(), SEED ^ 4);
    let q = QuantizedGraph::synthesize(lenet.topology.clone().into_graph(), SEED ^ 4);
    assert_eq!(q.weights, cnn.weights, "identical weight streams");

    let inputs = cnn.synth_inputs(2, 0xC4A4);
    let legacy = CnnEngine::tcd(NpeGeometry::PAPER).execute(&cnn, &inputs);
    let graph = GraphEngine::tcd(NpeGeometry::PAPER).execute(&q, &inputs);
    assert_eq!(graph.outputs, legacy.outputs, "graph == CNN engine");

    // Optimized: LeNet folds 4 hidden ReLUs and fuses both conv->pool
    // chains; still bit-exact.
    let (opt, stats) = optimize(&q);
    assert_eq!(stats.activations_folded, 4);
    assert_eq!(stats.pools_fused, 2);
    let opted = GraphEngine::tcd(NpeGeometry::PAPER).execute(&opt, &inputs);
    assert_eq!(opted.outputs, legacy.outputs, "optimized == legacy");
}

#[test]
fn fused_lowering_strictly_saves_rounds_on_a_zoo_entry() {
    // The acceptance bar: fused lowering reports strictly fewer rounds
    // than unfused on at least one zoo entry (the Inception twin-stem).
    let mut any_strict = false;
    for b in graph_benchmarks() {
        let q = QuantizedGraph::synthesize(b.graph.clone(), SEED);
        let (opt, _) = optimize(&q);
        let mut mapper = MapperTree::new(NpeGeometry::PAPER);
        let fused = lower_graph(&mut mapper, None, &opt.graph, 2, true).total_rounds();
        let unfused = lower_graph(&mut mapper, None, &q.graph, 2, false).total_rounds();
        assert!(
            fused <= unfused,
            "{}: fused {fused} > unfused {unfused}",
            b.network
        );
        if fused < unfused {
            any_strict = true;
        }
    }
    assert!(any_strict, "no zoo entry saved rounds under fused lowering");
}

#[test]
fn graph_outputs_are_geometry_independent() {
    let b = graph_benchmarks().remove(1); // TinyResNet
    let q = QuantizedGraph::synthesize(b.graph, SEED ^ 5);
    let inputs = q.synth_inputs(2, 0x6E0);
    let expect = q.forward_batch(&inputs);
    for geom in [
        NpeGeometry::WALKTHROUGH,
        NpeGeometry::PAPER,
        NpeGeometry::new(4, 4),
        NpeGeometry::new(1, 3),
    ] {
        let report = GraphEngine::tcd(geom).execute(&q, &inputs);
        assert_eq!(report.outputs, expect, "{geom:?}");
    }
}
