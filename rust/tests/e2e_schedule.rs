//! End-to-end scheduler validation: the paper's worked examples (Figs. 5
//! and 6) and schedule/execution consistency across geometries.

use tcd_npe::mapper::{Gamma, MapperTree, NpeGeometry};
use tcd_npe::model::{MlpTopology, QuantizedMlp};
use tcd_npe::npe::Controller;
use tcd_npe::tcdmac::MacKind;
use tcd_npe::util::check;

#[test]
fn fig5_all_four_configs_reproduced() {
    // Γ(3, I, 9) on 6×3: NPE(1,18) → 3 rolls @50%; NPE(6,3) → 3 rolls
    // @50%; NPE(2,9)/NPE(3,6) → 2 rolls @75% (the paper's Fig. 5 numbers).
    // The mapper must pick a 2-roll schedule.
    let mut m = MapperTree::new(NpeGeometry::WALKTHROUGH);
    let s = m.schedule_layer(Gamma::new(3, 50, 9));
    assert_eq!(s.total_rolls(), 2);
    assert!((s.utilization() - 0.75).abs() < 1e-9);
}

#[test]
fn fig6_schedule_structure() {
    // Γ(5, I, 7) on 6×3 → 3 rolls; the BFS event sequence covers all 35
    // (batch, neuron) pairs with config loads within capacity.
    let mut m = MapperTree::new(NpeGeometry::WALKTHROUGH);
    let s = m.schedule_layer(Gamma::new(5, 64, 7));
    assert_eq!(s.total_rolls(), 3);
    assert!(s.covers_exactly());
    let work: usize = s.events.iter().map(|e| e.work()).sum();
    assert_eq!(work, 35);
}

#[test]
fn executed_outputs_match_reference_across_geometries() {
    // The schedule machinery must be geometry-independent in *values*.
    let topo = MlpTopology::new(vec![30, 22, 9, 5]);
    let mlp = QuantizedMlp::synthesize(topo, 17);
    let inputs = mlp.synth_inputs(7, 23);
    let expect = mlp.forward_batch(&inputs);
    for geom in [
        NpeGeometry::WALKTHROUGH,
        NpeGeometry::PAPER,
        NpeGeometry::new(4, 4),
        NpeGeometry::new(1, 3),
        NpeGeometry::new(12, 2),
    ] {
        let (got, stats) = Controller::new(geom, MacKind::Tcd).run(&mlp, &inputs);
        assert_eq!(got, expect, "{geom:?}");
        assert!(stats.rolls > 0);
    }
}

#[test]
fn prop_random_models_random_geometries() {
    check::cases_n(0xE2E, 40, |g| {
        let topo = MlpTopology::new(vec![
            g.usize_in(1, 40),
            g.usize_in(1, 30),
            g.usize_in(1, 12),
        ]);
        let geom = NpeGeometry::new(g.usize_in(1, 10), g.usize_in(1, 6));
        let batches = g.usize_in(1, 9);
        let mlp = QuantizedMlp::synthesize(topo, g.u64());
        let inputs = mlp.synth_inputs(batches, g.u64());
        let (got, _) = Controller::new(geom, MacKind::Tcd).run(&mlp, &inputs);
        assert_eq!(got, mlp.forward_batch(&inputs));
    });
}

#[test]
fn larger_batches_improve_utilization_for_small_models() {
    // Multi-batch packing is what NPE(K, N) exists for (paper §III-B.1):
    // B=16 must not be less efficient than B=1 on a small model.
    let topo = MlpTopology::new(vec![10, 8, 3]);
    let mut m = MapperTree::new(NpeGeometry::PAPER);
    let u1 = m.schedule_model(&topo, 1).utilization();
    let u16 = m.schedule_model(&topo, 16).utilization();
    assert!(u16 > u1, "B=16 {u16:.2} vs B=1 {u1:.2}");
}
