//! Cross-module integration: mapper → controller → dataflows → memory,
//! end to end on the benchmark zoo (no PJRT required).

use tcd_npe::dataflow::{DataflowEngine, NlrEngine, OsEngine, RnaEngine};
use tcd_npe::mapper::{MapperTree, NpeGeometry};
use tcd_npe::memory::NpeMemorySystem;
use tcd_npe::model::{benchmarks, QuantizedMlp};
use tcd_npe::npe::Controller;
use tcd_npe::tcdmac::MacKind;

#[test]
fn every_benchmark_runs_all_four_dataflows_consistently() {
    let geom = NpeGeometry::PAPER;
    for b in benchmarks() {
        let mlp = QuantizedMlp::synthesize(b.topology.clone(), 1);
        let inputs = mlp.synth_inputs(3, 2);
        let expect = mlp.forward_batch(&inputs);
        let mut engines: Vec<Box<dyn DataflowEngine>> = vec![
            Box::new(OsEngine::tcd(geom)),
            Box::new(OsEngine::conventional(geom)),
            Box::new(NlrEngine::new(geom)),
            Box::new(RnaEngine::new(geom)),
        ];
        for e in engines.iter_mut() {
            let r = e.execute(&mlp, &inputs);
            assert_eq!(r.outputs, expect, "{} on {}", r.dataflow, b.dataset);
            assert!(r.cycles > 0 && r.time_ns > 0.0);
            assert!(r.energy.total_pj() > 0.0);
        }
    }
}

#[test]
fn schedules_cover_all_benchmarks_exactly() {
    let mut mapper = MapperTree::new(NpeGeometry::PAPER);
    for b in benchmarks() {
        for batches in [1, 7, 16] {
            let ms = mapper.schedule_model(&b.topology, batches);
            assert_eq!(ms.layers.len(), b.topology.n_transitions());
            for l in &ms.layers {
                assert!(l.covers_exactly(), "{} B={batches}", b.dataset);
            }
            assert!(ms.utilization() > 0.0 && ms.utilization() <= 1.0);
        }
    }
}

#[test]
fn bitexact_and_fast_paths_agree_on_a_real_benchmark() {
    // Wine (13:10:3) is small enough for the gate-level path.
    let b = benchmarks().into_iter().find(|b| b.dataset == "Wine").unwrap();
    let mlp = QuantizedMlp::synthesize(b.topology.clone(), 3);
    let inputs = mlp.synth_inputs(6, 4);
    let (fast, _) = Controller::new(NpeGeometry::PAPER, MacKind::Tcd).run(&mlp, &inputs);
    let (slow, _) = Controller::new(NpeGeometry::PAPER, MacKind::Tcd)
        .bitexact(true)
        .run(&mlp, &inputs);
    assert_eq!(fast, slow);
    assert_eq!(fast, mlp.forward_batch(&inputs));
}

#[test]
fn memory_traffic_scales_with_model_size() {
    let mut mapper = MapperTree::new(NpeGeometry::PAPER);
    let small = benchmarks().into_iter().find(|b| b.dataset == "Wine").unwrap();
    let large = benchmarks().into_iter().find(|b| b.dataset == "MNIST").unwrap();
    let t = |b: &tcd_npe::model::Benchmark, mapper: &mut MapperTree| {
        let mlp = QuantizedMlp::synthesize(b.topology.clone(), 1);
        let inputs = mlp.synth_inputs(4, 2);
        let schedule = mapper.schedule_model(&b.topology, 4);
        let mut mem = NpeMemorySystem::new();
        mem.account_schedule(&schedule, &mlp, &inputs)
    };
    let ts = t(&small, &mut mapper);
    let tl = t(&large, &mut mapper);
    assert!(tl.wmem_row_reads > 10 * ts.wmem_row_reads);
    assert!(tl.dram_bits_in > 10 * ts.dram_bits_in);
}

#[test]
fn utilization_improves_with_mapper_vs_naive_single_batch() {
    // The Algorithm-1 multi-batch packing is the point of the mapper:
    // for small layers, batching K>1 models per roll beats NPE(1, 128).
    let mut mapper = MapperTree::new(NpeGeometry::PAPER);
    let b = benchmarks().into_iter().find(|b| b.dataset == "Iris").unwrap();
    let ms = mapper.schedule_model(&b.topology, 16);
    // Naive: one batch at a time, one roll per batch per layer at least.
    let naive_rolls = 16 * b.topology.n_transitions();
    assert!(
        ms.total_rolls() < naive_rolls,
        "mapper {} vs naive {naive_rolls}",
        ms.total_rolls()
    );
}
