//! Multi-tenant registry, end to end:
//!
//! * routed submits are bit-exact against a dedicated single-tenant
//!   service for every zoo class (MLP, CNN, DAG) sharing one pool;
//! * an unknown tenant is a typed `UnknownTenant` that occupies no
//!   queue space and moves no tenant's counters;
//! * a shed storm on one tenant never shows up in another tenant's
//!   metrics lane;
//! * two tenants serving the same topology share Algorithm-1 schedules:
//!   the second tenant's traffic is all cache hits;
//! * the merged Prometheus exposition labels every tenant's samples.

use std::time::Duration;
use tcd_npe::conv::QuantizedCnn;
use tcd_npe::coordinator::BatcherConfig;
use tcd_npe::graph::QuantizedGraph;
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{benchmark_by_name, cnn_benchmark_by_name, graph_benchmarks, QuantizedMlp};
use tcd_npe::serve::{AdmissionPolicy, NpeService, ServeError};
use tcd_npe::ModelRegistry;

fn iris() -> QuantizedMlp {
    let b = benchmark_by_name("Iris").expect("Iris is in Table IV");
    QuantizedMlp::synthesize(b.topology.clone(), 0x1E9_1)
}

fn lenet() -> QuantizedCnn {
    let b = cnn_benchmark_by_name("LeNet-5").expect("LeNet-5 is in the CNN zoo");
    QuantizedCnn::synthesize(b.topology.clone(), 0x1E9_2)
}

fn dag() -> QuantizedGraph {
    let benches = graph_benchmarks();
    QuantizedGraph::synthesize(benches[0].graph.clone(), 0x1E9_3)
}

#[test]
fn routed_submits_match_dedicated_services_for_every_zoo_class() {
    let (mlp, cnn, graph) = (iris(), lenet(), dag());
    let batcher = BatcherConfig::new(2, Duration::from_millis(2));
    let registry = ModelRegistry::builder()
        .devices(vec![NpeGeometry::PAPER; 2])
        .batcher(batcher)
        .register("iris", mlp.clone())
        .register("lenet", cnn.clone())
        .register("dag", graph.clone())
        .build()
        .expect("valid registry");

    // Route 3 requests per tenant through the shared pool and compare
    // against a dedicated single-tenant service *and* the host-side
    // reference forward pass.
    let cases: Vec<(&str, Vec<Vec<i16>>, Vec<Vec<i16>>)> = vec![
        ("iris", mlp.synth_inputs(3, 0xE2E), mlp.forward_batch(&mlp.synth_inputs(3, 0xE2E))),
        ("lenet", cnn.synth_inputs(3, 0xE2E), cnn.forward_batch(&cnn.synth_inputs(3, 0xE2E))),
        ("dag", graph.synth_inputs(3, 0xE2E), graph.forward_batch(&graph.synth_inputs(3, 0xE2E))),
    ];
    let dedicated = vec![
        NpeService::builder(mlp).geometry(NpeGeometry::PAPER).batcher(batcher).build().unwrap(),
        NpeService::builder(cnn).geometry(NpeGeometry::PAPER).batcher(batcher).build().unwrap(),
        NpeService::builder(graph).geometry(NpeGeometry::PAPER).batcher(batcher).build().unwrap(),
    ];
    for ((tenant, inputs, expect), solo) in cases.iter().zip(&dedicated) {
        for (x, want) in inputs.iter().zip(expect) {
            let routed =
                registry.submit(tenant, x.clone()).expect("routed").wait().expect("answered");
            let alone = solo.submit(x.clone()).expect("admitted").wait().expect("answered");
            assert_eq!(&routed.output, want, "{tenant}: shared pool == reference");
            assert_eq!(routed.output, alone.output, "{tenant}: shared pool == dedicated service");
        }
    }
    for (tenant, inputs, _) in &cases {
        assert_eq!(
            registry.metrics(tenant).expect("known tenant").requests,
            inputs.len() as u64,
            "{tenant}: per-tenant request counter"
        );
    }
    for solo in dedicated {
        solo.shutdown().expect("dedicated shutdown");
    }
    registry.shutdown().expect("registry shutdown");
}

#[test]
fn unknown_tenant_is_typed_and_occupies_nothing() {
    let registry = ModelRegistry::builder()
        .devices([NpeGeometry::PAPER])
        .register("iris", iris())
        .build()
        .expect("valid registry");
    for _ in 0..3 {
        let err = registry.submit("mystery", vec![0; 4]).expect_err("unknown tenant");
        assert_eq!(err, ServeError::UnknownTenant { tenant: "mystery".into() });
    }
    assert_eq!(registry.in_flight("iris").expect("known"), 0, "no queue space consumed");
    let m = registry.metrics("iris").expect("known");
    assert_eq!(
        (m.requests, m.rejected_requests, m.shed_requests),
        (0, 0, 0),
        "misroutes move no tenant's counters"
    );
    registry.shutdown().expect("registry shutdown");
}

#[test]
fn shed_storm_on_one_tenant_stays_out_of_the_others_lane() {
    // The batcher can only flush at shutdown (batch 64, 30 s deadline),
    // so admitted requests park deterministically: greedy's Reject{2}
    // bound refuses 4 of its 6 submits while quiet's Block admits all 3.
    let greedy_model = iris();
    let quiet_model = iris();
    let registry = ModelRegistry::builder()
        .devices([NpeGeometry::PAPER])
        .batcher(BatcherConfig::new(64, Duration::from_secs(30)))
        .register_with("greedy", greedy_model.clone(), AdmissionPolicy::Reject { max_depth: 2 })
        .register("quiet", quiet_model.clone())
        .build()
        .expect("valid registry");

    let storm = greedy_model.synth_inputs(6, 0x5702);
    let mut admitted = Vec::new();
    let mut refused = 0;
    for x in &storm {
        match registry.submit("greedy", x.clone()) {
            Ok(t) => admitted.push(t),
            Err(ServeError::QueueFull { max_depth: 2, .. }) => refused += 1,
            other => panic!("unexpected outcome {other:?}"),
        }
    }
    assert_eq!(admitted.len(), 2, "Reject{{2}} admits exactly the bound");
    assert_eq!(refused, 4);

    let quiet_inputs = quiet_model.synth_inputs(3, 0x9013);
    let quiet_expect = quiet_model.forward_batch(&quiet_inputs);
    let quiet_tickets: Vec<_> = quiet_inputs
        .iter()
        .map(|x| registry.submit("quiet", x.clone()).expect("Block admits everything"))
        .collect();

    assert_eq!(registry.metrics("greedy").expect("known").shed_requests, 4);
    assert_eq!(
        registry.metrics("quiet").expect("known").shed_requests,
        0,
        "the storm never bleeds into the quiet tenant's lane"
    );
    assert_eq!(registry.in_flight("greedy").expect("known"), 2);
    assert_eq!(registry.in_flight("quiet").expect("known"), 3);

    registry.shutdown().expect("flushes the parked work");
    for (t, want) in quiet_tickets.into_iter().zip(quiet_expect) {
        assert_eq!(
            t.wait_timeout(Duration::from_secs(5)).expect("answered at shutdown").output,
            want,
            "quiet tenant answered bit-exactly despite the storm"
        );
    }
    for t in admitted {
        t.wait_timeout(Duration::from_secs(5)).expect("admitted greedy work is still served");
    }
}

#[test]
fn same_topology_tenants_share_the_schedule_cache() {
    // Same topology, different weights: tenant "b" maps no shape "a"
    // hasn't already memoized, so b's traffic adds hits and zero misses.
    let b_model = {
        let bench = benchmark_by_name("Iris").expect("Iris is in Table IV");
        QuantizedMlp::synthesize(bench.topology.clone(), 0xB0B)
    };
    let registry = ModelRegistry::builder()
        .devices([NpeGeometry::PAPER])
        .batcher(BatcherConfig::new(1, Duration::ZERO))
        .register("a", iris())
        .register("b", b_model.clone())
        .build()
        .expect("valid registry");

    for x in iris().synth_inputs(4, 0xA11) {
        registry.submit("a", x).expect("routed").wait().expect("answered");
    }
    let after_a = registry.cache().stats();
    assert!(after_a.misses > 0, "first tenant populates the shared cache");

    for x in b_model.synth_inputs(4, 0xB22) {
        registry.submit("b", x).expect("routed").wait().expect("answered");
    }
    let after_b = registry.cache().stats();
    assert_eq!(
        after_b.misses, after_a.misses,
        "the second tenant's shapes were all memoized already"
    );
    assert!(after_b.hits > after_a.hits, "b's lookups landed as shared hits");
    registry.shutdown().expect("registry shutdown");
}

#[test]
fn prometheus_exposition_labels_every_tenant() {
    let registry = ModelRegistry::builder()
        .devices([NpeGeometry::PAPER])
        .batcher(BatcherConfig::new(1, Duration::ZERO))
        .register("iris", iris())
        .register("lenet", lenet())
        .build()
        .expect("valid registry");
    let m = iris();
    for x in m.synth_inputs(2, 0x9E7) {
        registry.submit("iris", x).expect("routed").wait().expect("answered");
    }
    let text = registry.prometheus_text();
    assert!(text.contains("npe_requests_total{tenant=\"iris\"} 2"), "{text}");
    assert!(text.contains("npe_requests_total{tenant=\"lenet\"} 0"), "{text}");
    // Every sample line carries a tenant label; headers stay bare.
    for line in text.lines().filter(|l| !l.is_empty() && !l.starts_with('#')) {
        assert!(line.contains("tenant=\""), "unlabeled sample: {line}");
    }
    // The per-tenant snapshot carries the same label.
    let snap = registry.metrics_snapshot("iris").expect("known");
    assert!(snap.to_json().contains("\"tenant\":\"iris\""));
    registry.shutdown().expect("registry shutdown");
}
