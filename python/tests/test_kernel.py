"""L1 correctness: the TCD carry-save Pallas kernel vs the pure-jnp oracle.

This is the core build-time correctness signal: if the kernel and ref.py
agree (and ref.py agrees with the Rust reference — test_cross_language),
the whole stack computes the same quantized MLP.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.ref import mlp_forward_ref, mlp_layer_ref, quantize_acc
from compile.kernels.tcd_mac import tcd_mlp_forward, tcd_mlp_layer


def rand_i16(rng, shape, lo=-32768, hi=32767):
    return rng.integers(lo, hi + 1, size=shape, dtype=np.int64).astype(np.int16)


@pytest.mark.parametrize("relu", [False, True])
@pytest.mark.parametrize(
    "b,i,o,block_k",
    [
        (1, 1, 1, 128),
        (2, 7, 3, 4),      # I not a multiple of block_k → padding path
        (4, 128, 16, 128), # exactly one step
        (3, 300, 5, 128),  # multi-step with remainder
        (8, 784, 700, 128),  # MNIST layer shape
    ],
)
def test_kernel_matches_ref_shapes(relu, b, i, o, block_k):
    rng = np.random.default_rng(b * 1000 + i + o)
    # Magnitudes like the synthetic models (occasional saturation).
    x = rand_i16(rng, (b, i), -127, 127)
    w = rand_i16(rng, (o, i), -96, 96)
    got = tcd_mlp_layer(jnp.asarray(x), jnp.asarray(w), relu=relu, block_k=block_k)
    want = mlp_layer_ref(jnp.asarray(x), jnp.asarray(w), relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_kernel_full_range_values():
    # Full int16 range, including i16::MIN products and saturation.
    rng = np.random.default_rng(7)
    x = rand_i16(rng, (3, 50))
    w = rand_i16(rng, (4, 50))
    got = tcd_mlp_layer(jnp.asarray(x), jnp.asarray(w), relu=False, block_k=16)
    want = mlp_layer_ref(jnp.asarray(x), jnp.asarray(w), relu=False)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_saturation_rails():
    # One huge positive and one huge negative accumulator.
    x = jnp.full((1, 64), 127, jnp.int16)
    w_pos = jnp.full((1, 64), 96, jnp.int16)
    w_neg = jnp.full((1, 64), -96, jnp.int16)
    y_pos = tcd_mlp_layer(x, w_pos, relu=False, block_k=16)
    y_neg = tcd_mlp_layer(x, w_neg, relu=False, block_k=16)
    acc = 127 * 96 * 64
    assert int(y_pos[0, 0]) == int(quantize_acc(jnp.int64(acc)))
    assert int(y_neg[0, 0]) == int(quantize_acc(jnp.int64(-acc)))


def test_relu_zeroes_negatives():
    x = jnp.array([[256]], jnp.int16)  # 1.0 in Q7.8
    w = jnp.array([[-256]], jnp.int16)  # -1.0
    assert int(tcd_mlp_layer(x, w, relu=True)[0, 0]) == 0
    assert int(tcd_mlp_layer(x, w, relu=False)[0, 0]) == -256


def test_forward_chain_matches_ref():
    rng = np.random.default_rng(11)
    layers = [20, 12, 6, 4]
    x = rand_i16(rng, (5, layers[0]), -127, 127)
    ws = [
        rand_i16(rng, (o, i), -96, 96)
        for i, o in zip(layers[:-1], layers[1:])
    ]
    got = tcd_mlp_forward(jnp.asarray(x), [jnp.asarray(w) for w in ws])
    want = mlp_forward_ref(jnp.asarray(x), [jnp.asarray(w) for w in ws])
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 4),
    i=st.integers(1, 96),
    o=st.integers(1, 12),
    block_k=st.sampled_from([4, 16, 128]),
    relu=st.booleans(),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_hypothesis(b, i, o, block_k, relu, seed):
    rng = np.random.default_rng(seed)
    x = rand_i16(rng, (b, i))
    w = rand_i16(rng, (o, i))
    got = tcd_mlp_layer(jnp.asarray(x), jnp.asarray(w), relu=relu, block_k=block_k)
    want = mlp_layer_ref(jnp.asarray(x), jnp.asarray(w), relu=relu)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
