"""L2 correctness: synthetic-model generation and the lowered forward
function, including the cross-language RNG pins against the Rust side."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.model import (
    BENCHMARKS,
    FEATURE_BOUND,
    WEIGHT_BOUND,
    forward_fn,
    synth_inputs,
    synth_weights,
)
from compile.kernels.ref import mlp_forward_ref
from compile.rng import bounded_i16, splitmix64_stream


def test_splitmix_pinned_against_rust():
    # Values printed by rust/src/util/rng.rs (SplitMix64::new(42)).
    want = [0xBDD732262FEB6E95, 0x28EFE333B266F103, 0x47526757130F9F52, 0x581CE1FF0E4AE394]
    got = [int(v) for v in splitmix64_stream(42, 4)]
    assert got == want


def test_bounded_i16_pinned_against_rust():
    # SplitMix64::new(0xF16_10).next_i16_bounded(96), first 8 values.
    want = [-4, 34, 84, -42, 4, -48, 53, -40]
    got = [int(v) for v in bounded_i16(0xF1610, 8, 96)]
    assert got == want


def test_benchmarks_match_table4():
    assert len(BENCHMARKS) == 7
    by_name = {b.dataset: b.layers for b in BENCHMARKS}
    assert by_name["MNIST"] == (784, 700, 10)
    assert by_name["Iris"] == (4, 10, 5, 3)
    assert by_name["Fashion MNIST"] == (728, 256, 128, 100, 10)


def test_synth_shapes_and_bounds():
    layers = (13, 10, 3)
    ws = synth_weights(layers, 5)
    assert [w.shape for w in ws] == [(10, 13), (3, 10)]
    assert all(np.abs(w).max() <= WEIGHT_BOUND for w in ws)
    x = synth_inputs(layers, 6, 9)
    assert x.shape == (6, 13)
    assert np.abs(x).max() <= FEATURE_BOUND


def test_weights_deterministic_per_seed():
    a = synth_weights((4, 3, 2), 1)
    b = synth_weights((4, 3, 2), 1)
    c = synth_weights((4, 3, 2), 2)
    assert all((x == y).all() for x, y in zip(a, b))
    assert any((x != y).any() for x, y in zip(a, c))


@pytest.mark.parametrize("use_pallas", [False, True])
def test_forward_fn_matches_ref(use_pallas):
    layers = (12, 9, 4)
    ws = synth_weights(layers, 3)
    x = synth_inputs(layers, 5, 4)
    f = jax.jit(forward_fn(len(ws), use_pallas=use_pallas))
    (y,) = f(
        jnp.asarray(x, jnp.int32), *[jnp.asarray(w, jnp.int32) for w in ws]
    )
    want = mlp_forward_ref(
        jnp.asarray(x, jnp.int16), [jnp.asarray(w) for w in ws]
    )
    assert y.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(y, np.int16), np.asarray(want))


def test_forward_fn_output_in_i16_range():
    layers = (8, 6, 2)
    ws = synth_weights(layers, 8)
    x = synth_inputs(layers, 3, 9)
    f = forward_fn(len(ws))
    (y,) = f(jnp.asarray(x, jnp.int32), *[jnp.asarray(w, jnp.int32) for w in ws])
    y = np.asarray(y)
    assert y.min() >= -(1 << 15) and y.max() <= (1 << 15) - 1
