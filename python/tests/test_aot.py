"""AOT pipeline: lowered HLO text must be loadable-shaped (parameters in
the (x, w_0, …) order, s32 interface, tuple result) and numerically equal
to the oracle when round-tripped through XLA compilation here."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from compile.aot import lower_benchmark, to_hlo_text
from compile.model import BENCHMARKS, forward_fn, synth_inputs, synth_weights
from compile.kernels.ref import mlp_forward_ref


def small_bench():
    return next(b for b in BENCHMARKS if b.dataset == "Iris")


def test_hlo_text_structure():
    text = lower_benchmark(small_bench(), batch=4, use_pallas=True)
    assert "HloModule" in text
    assert "ENTRY" in text
    # 1 input + 3 weight parameters for 4:10:5:3.
    for i in range(4):
        assert f"parameter({i})" in text
    assert "s32[4,4]" in text or "s32[4, 4]" in text  # input x. (B=4, I=4)


def test_pallas_and_ref_lower_to_same_numbers():
    bench = small_bench()
    ws = synth_weights(bench.layers, 3)
    x = synth_inputs(bench.layers, 4, 5)
    outs = []
    for use_pallas in (True, False):
        f = jax.jit(forward_fn(len(ws), use_pallas=use_pallas))
        (y,) = f(jnp.asarray(x, jnp.int32), *[jnp.asarray(w, jnp.int32) for w in ws])
        outs.append(np.asarray(y))
    np.testing.assert_array_equal(outs[0], outs[1])
    want = mlp_forward_ref(jnp.asarray(x, jnp.int16), [jnp.asarray(w) for w in ws])
    np.testing.assert_array_equal(outs[0].astype(np.int16), np.asarray(want))


@pytest.mark.parametrize("bench", BENCHMARKS, ids=lambda b: b.slug)
def test_all_benchmarks_lower(bench):
    # Lowering (not compiling) every topology must succeed and mention
    # the right output arity.
    text = lower_benchmark(bench, batch=2, use_pallas=True)
    assert "HloModule" in text
    assert f"s32[2,{bench.layers[-1]}]" in text.replace(" ", "").replace(
        "s32[2,", "s32[2,"
    )
