"""Build-time compile package (never imported at runtime).

The TCD carry-save planes are int64 (exactness headroom over the int32
products -- mirrors the Rust 40-bit ACC planes), so x64 must be enabled
before any jax arrays exist.
"""

import jax

jax.config.update("jax_enable_x64", True)
