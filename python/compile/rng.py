"""SplitMix64 — the deterministic PRNG shared with the Rust side.

`rust/src/util/rng.rs` implements the identical algorithm; both sides must
produce identical synthetic weights/features so the NPE simulator and the
JAX/PJRT artifacts operate on the same networks with no weight-file
interchange. The cross-language tests pin the streams.
"""

import numpy as np

GOLDEN = np.uint64(0x9E3779B97F4A7C15)
_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)


def splitmix64_stream(seed: int, n: int) -> np.ndarray:
    """First `n` outputs of SplitMix64 seeded with `seed` (uint64)."""
    with np.errstate(over="ignore"):
        i = np.arange(1, n + 1, dtype=np.uint64)
        z = np.uint64(seed) + i * GOLDEN
        z = (z ^ (z >> np.uint64(30))) * _M1
        z = (z ^ (z >> np.uint64(27))) * _M2
        return z ^ (z >> np.uint64(31))


def bounded_i16(seed: int, n: int, bound: int) -> np.ndarray:
    """Mirror of `SplitMix64::next_i16_bounded`: uniform in [-bound, bound]."""
    span = np.uint64(2 * bound + 1)
    vals = splitmix64_stream(seed, n) % span
    return (vals.astype(np.int64) - bound).astype(np.int16)


def layer_seed(seed: int, layer: int) -> int:
    """Mirror of `QuantizedMlp::synthesize`'s per-layer seed derivation."""
    with np.errstate(over="ignore"):
        return int(np.uint64(seed) ^ (GOLDEN * np.uint64(layer + 1)))
