"""AOT lowering: jax → HLO text artifacts for the Rust PJRT runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax ≥ 0.5 emits protos with 64-bit instruction ids that the crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage: python -m compile.aot [--out-dir DIR] [--batch B] [--no-pallas]
Writes one `<slug>_b<B>.hlo.txt` per Table-IV benchmark plus
`manifest.txt` (`name batch topology seed` per line — parsed by
`rust/src/runtime/artifact.rs`).
"""

import argparse
import pathlib

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import BENCHMARKS, forward_fn

#: Default batch shape of the artifacts (also the coordinator's batch).
DEFAULT_BATCH = 8
#: Seed recorded in the manifest (the Rust side synthesizes weights and
#: inputs from it; weights are runtime inputs so this only seeds inputs).
MANIFEST_SEED = 0xF1610


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_benchmark(bench, batch: int, use_pallas: bool) -> str:
    """Lower one benchmark's forward pass to HLO text."""
    layers = bench.layers
    n_trans = len(layers) - 1
    specs = [jax.ShapeDtypeStruct((batch, layers[0]), jnp.int32)]
    specs += [
        jax.ShapeDtypeStruct((o, i), jnp.int32)
        for i, o in zip(layers[:-1], layers[1:])
    ]
    lowered = jax.jit(forward_fn(n_trans, use_pallas=use_pallas)).lower(*specs)
    return to_hlo_text(lowered)


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=DEFAULT_BATCH)
    ap.add_argument(
        "--no-pallas",
        action="store_true",
        help="lower the pure-jnp reference instead of the Pallas kernel",
    )
    args = ap.parse_args()

    out = pathlib.Path(args.out_dir)
    out.mkdir(parents=True, exist_ok=True)
    manifest_lines = ["# name batch topology seed"]
    for bench in BENCHMARKS:
        name = f"{bench.slug}_b{args.batch}"
        text = lower_benchmark(bench, args.batch, use_pallas=not args.no_pallas)
        path = out / f"{name}.hlo.txt"
        path.write_text(text)
        manifest_lines.append(
            f"{name} {args.batch} {bench.topology_str} {MANIFEST_SEED}"
        )
        print(f"wrote {path} ({len(text)} chars)")
    (out / "manifest.txt").write_text("\n".join(manifest_lines) + "\n")
    print(f"wrote {out / 'manifest.txt'} ({len(BENCHMARKS)} artifacts)")


if __name__ == "__main__":
    main()
