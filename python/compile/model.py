"""L2: the quantized MLP model — topology zoo, synthetic weights, and the
forward function that `aot.py` lowers to HLO.

Weights are *runtime inputs* of the lowered HLO (not baked constants): the
Rust leader generates them with the mirrored SplitMix64 stream and feeds
them per call, so one artifact per (topology, batch) serves any seed.
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels.ref import mlp_forward_ref
from .kernels.tcd_mac import tcd_mlp_forward
from .rng import bounded_i16, layer_seed

# Mirrors rust/src/model/mlp.rs.
WEIGHT_BOUND = 96
FEATURE_BOUND = 127


@dataclass(frozen=True)
class Benchmark:
    """One Table-IV row (topology as printed in the paper)."""

    dataset: str
    layers: tuple

    @property
    def slug(self) -> str:
        return self.dataset.lower().replace(" ", "_").replace("-", "_")

    @property
    def topology_str(self) -> str:
        return ":".join(str(n) for n in self.layers)


#: Table IV (same order/values as rust/src/model/zoo.rs).
BENCHMARKS = [
    Benchmark("MNIST", (784, 700, 10)),
    Benchmark("Adult", (14, 48, 2)),
    Benchmark("Mibench data", (8, 140, 2)),
    Benchmark("Wine", (13, 10, 3)),
    Benchmark("Iris", (4, 10, 5, 3)),
    Benchmark("Poker Hands", (10, 85, 50, 10)),
    Benchmark("Fashion MNIST", (728, 256, 128, 100, 10)),
]


def synth_weights(layers, seed: int):
    """Mirror of `QuantizedMlp::synthesize`: one [O, I] int16 matrix per
    transition, drawn from the layer-indexed SplitMix64 stream."""
    out = []
    for l, (i, o) in enumerate(zip(layers[:-1], layers[1:])):
        flat = bounded_i16(layer_seed(seed, l), i * o, WEIGHT_BOUND)
        out.append(flat.reshape(o, i))
    return out


def synth_inputs(layers, batches: int, seed: int):
    """Mirror of `QuantizedMlp::synth_inputs`."""
    flat = bounded_i16(seed, batches * layers[0], FEATURE_BOUND)
    return flat.reshape(batches, layers[0])


def forward_fn(n_layers: int, use_pallas: bool = True):
    """The function lowered to HLO.

    Interface dtypes are s32 (the widest the `xla` crate's Literal
    helpers cover comfortably); values are i16-ranged. Signature:
    `f(x: s32[B, I], w_0: s32[H1, I], …) -> (y: s32[B, O],)`.
    """

    def f(x, *weights):
        assert len(weights) == n_layers
        h = x.astype(jnp.int16)
        ws = [w.astype(jnp.int16) for w in weights]
        y = tcd_mlp_forward(h, ws) if use_pallas else mlp_forward_ref(h, ws)
        return (y.astype(jnp.int32),)

    return f
