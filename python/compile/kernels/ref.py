"""Pure-jnp oracle for the quantized MLP layer — the correctness ground
truth for the Pallas kernel and the semantic twin of the Rust reference
(`rust/src/model/mlp.rs::forward_sample` / `model::fixedpoint`).

Fixed-point contract (pinned on both sides):
* activations and weights are signed 16-bit Q7.8;
* the dot product accumulates exactly (64-bit here; the Rust TCD-MAC's
  40-bit planes never wrap at the synthetic-model magnitudes — tested);
* quantization is an arithmetic right shift by FRAC_BITS with saturation
  to i16 (Fig. 4), ReLU on hidden layers only.
"""

import jax.numpy as jnp

FRAC_BITS = 8
Q_MIN = -(1 << 15)
Q_MAX = (1 << 15) - 1


def quantize_acc(acc):
    """Arithmetic shift + saturate — `model::fixedpoint::quantize_acc`."""
    return jnp.clip(acc >> FRAC_BITS, Q_MIN, Q_MAX).astype(jnp.int16)


def mlp_layer_ref(x, w, relu: bool):
    """One quantized layer: x [B, I] i16, w [O, I] i16 → [B, O] i16."""
    acc = jnp.matmul(
        x.astype(jnp.int64), w.astype(jnp.int64).T, preferred_element_type=jnp.int64
    )
    q = quantize_acc(acc)
    return jnp.maximum(q, 0) if relu else q


def mlp_forward_ref(x, weights):
    """Full forward pass; ReLU on all but the last transition."""
    h = x
    for l, w in enumerate(weights):
        h = mlp_layer_ref(h, w, relu=(l + 1 < len(weights)))
    return h
