"""L1 Pallas kernel: the TCD-MAC insight as a carry-save reduction.

Hardware adaptation (DESIGN.md §5): the ASIC defers carry propagation
across the *cycles* of a dot-product stream; on a TPU-shaped machine the
same insight applies across the *K-blocks* of a tiled matmul — keep the
accumulator in redundant (sum, carry) form in VMEM scratch between grid
steps, compress each new partial-product block with bitwise 3:2 logic
(XOR/majority — the GEN layer), and resolve the carries exactly once at
the K-tail (the CPM cycle / PCPA).

Per grid step k (the CDM cycle):
    p      = x[:, kblk] · wᵀ[kblk, :]            # DRU + intra-block CEL
    s, c   = s ^ p ^ c,  ((s&p)|(s&c)|(p&c)) << 1  # GEN: defer the carry
invariant (property-tested):  s + c  ==  Σ_k p_k   (mod 2^64)
Final step: acc = s + c (PCPA), then the Fig. 4 quantize + ReLU unit.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; real-TPU efficiency is estimated analytically in DESIGN.md.
BlockSpec streams one (B, K_BLK) feature tile and one (O, K_BLK) weight
tile per step — the software analog of the Fig.-7 row-buffer schedule.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import FRAC_BITS, Q_MAX, Q_MIN

# Default K-tile: 128 lanes, matching the W-Mem row of 128 words (Fig. 7).
DEFAULT_BLOCK_K = 128


def _tcd_layer_kernel(x_ref, w_ref, o_ref, s_ref, c_ref, *, nsteps, relu):
    """One grid step of the carry-deferring layer reduction."""
    k = pl.program_id(0)

    @pl.when(k == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)
        c_ref[...] = jnp.zeros_like(c_ref)

    # DRU + intra-block compression: the partial-product block sum.
    x = x_ref[...].astype(jnp.int64)  # [B, K_BLK]
    w = w_ref[...].astype(jnp.int64)  # [O, K_BLK]
    p = jax.lax.dot_general(
        x, w, (((1,), (1,)), ((), ())), preferred_element_type=jnp.int64
    )  # [B, O]

    # GEN layer: 3:2-compress (s, c, p) and defer the generate bits.
    s = s_ref[...]
    c = c_ref[...]
    s_ref[...] = s ^ p ^ c
    c_ref[...] = ((s & p) | (s & c) | (p & c)) << 1

    @pl.when(k == nsteps - 1)
    def _resolve():
        # CPM cycle: the deferred PCPA resolves the redundant planes...
        acc = s_ref[...] + c_ref[...]
        # ...and the Fig.-4 unit quantizes (+ optionally rectifies).
        q = jnp.clip(acc >> FRAC_BITS, Q_MIN, Q_MAX).astype(jnp.int16)
        o_ref[...] = jnp.maximum(q, 0) if relu else q


def tcd_mlp_layer(x, w, relu: bool, block_k: int = DEFAULT_BLOCK_K):
    """Quantized MLP layer via the TCD carry-save Pallas kernel.

    x: [B, I] int16 activations; w: [O, I] int16 weights → [B, O] int16.
    I is zero-padded to a multiple of `block_k` (zero products change
    nothing — exactly like the NPE streaming idle lanes).
    """
    b, i = x.shape
    o, i2 = w.shape
    assert i == i2, f"fan-in mismatch: {i} vs {i2}"
    kb = min(block_k, max(i, 1))
    pad = (-i) % kb
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
        w = jnp.pad(w, ((0, 0), (0, pad)))
    nsteps = (i + pad) // kb

    kernel = functools.partial(_tcd_layer_kernel, nsteps=nsteps, relu=relu)
    out, _s, _c = pl.pallas_call(
        kernel,
        grid=(nsteps,),
        in_specs=[
            pl.BlockSpec((b, kb), lambda k: (0, k)),  # feature row buffer
            pl.BlockSpec((o, kb), lambda k: (0, k)),  # weight row buffer
        ],
        out_specs=[
            pl.BlockSpec((b, o), lambda k: (0, 0)),  # resolved outputs
            pl.BlockSpec((b, o), lambda k: (0, 0)),  # ORU plane
            pl.BlockSpec((b, o), lambda k: (0, 0)),  # CBU plane
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, o), jnp.int16),
            jax.ShapeDtypeStruct((b, o), jnp.int64),
            jax.ShapeDtypeStruct((b, o), jnp.int64),
        ],
        interpret=True,
    )(x, w)
    return out


def tcd_mlp_forward(x, weights, block_k: int = DEFAULT_BLOCK_K):
    """Full MLP forward through the Pallas layer kernel."""
    h = x
    for l, w in enumerate(weights):
        h = tcd_mlp_layer(h, w, relu=(l + 1 < len(weights)), block_k=block_k)
    return h
