//! Dataflow comparison (the Fig. 9/10 story on one benchmark): run the
//! same model through OS-TCD, OS-conv, NLR and RNA and print the
//! time/energy table.
//!
//! Run: `cargo run --release --example dataflow_compare [dataset] [batches]`

use tcd_npe::dataflow::{DataflowEngine, NlrEngine, OsEngine, RnaEngine};
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{benchmark_by_name, QuantizedMlp};
use tcd_npe::util::TextTable;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let dataset = args.first().map(String::as_str).unwrap_or("Adult");
    let batches: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(10);

    let bench = benchmark_by_name(dataset).unwrap_or_else(|| {
        eprintln!("unknown dataset {dataset}; try MNIST, Adult, Wine, Iris, ...");
        std::process::exit(1);
    });
    let mlp = QuantizedMlp::synthesize(bench.topology.clone(), 99);
    let inputs = mlp.synth_inputs(batches, 100);
    println!(
        "{} ({}), B={batches} on the 16x8 array\n",
        bench.dataset,
        bench.topology.display()
    );

    let geom = NpeGeometry::PAPER;
    let mut engines: Vec<Box<dyn DataflowEngine>> = vec![
        Box::new(OsEngine::tcd(geom)),
        Box::new(OsEngine::conventional(geom)),
        Box::new(NlrEngine::new(geom)),
        Box::new(RnaEngine::new(geom)),
    ];
    let mut t = TextTable::new(vec![
        "Dataflow", "MAC", "Cycles", "Time (us)", "PE dyn (uJ)", "Mem (uJ)", "Total (uJ)",
    ]);
    let mut first_outputs: Option<Vec<Vec<i16>>> = None;
    for e in engines.iter_mut() {
        let r = e.execute(&mlp, &inputs);
        if let Some(f) = &first_outputs {
            assert_eq!(f, &r.outputs, "dataflows must agree on values");
        } else {
            first_outputs = Some(r.outputs.clone());
        }
        t.row(vec![
            r.dataflow.to_string(),
            r.mac.to_string(),
            r.cycles.to_string(),
            format!("{:.2}", r.time_us()),
            format!("{:.3}", r.energy.pe_dynamic_pj / 1e6),
            format!("{:.3}", (r.energy.mem_dynamic_pj + r.energy.mem_leak_pj) / 1e6),
            format!("{:.3}", r.energy.total_pj() / 1e6),
        ]);
    }
    println!("{}", t.render());
    println!("(all four dataflows produced identical neuron values)");
}
