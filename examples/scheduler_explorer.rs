//! Scheduler explorer: reproduce the paper's Fig. 5 and Fig. 6
//! walkthroughs on the 6×3 array, then show the 16×8 schedule for a real
//! benchmark.
//!
//! Run: `cargo run --release --example scheduler_explorer`

use tcd_npe::mapper::{Gamma, MapperTree, NpeGeometry};
use tcd_npe::model::benchmarks;

fn main() {
    let mut m = MapperTree::new(NpeGeometry::WALKTHROUGH);

    println!("== Fig. 5: Γ(3, I, 9) on the 6x3 array ==");
    println!("supported configs: {:?}", NpeGeometry::WALKTHROUGH.configs());
    let s = m.schedule_layer(Gamma::new(3, 100, 9));
    println!(
        "optimal: {} rolls, utilization {:.0}%",
        s.total_rolls(),
        s.utilization() * 100.0
    );
    for e in &s.events {
        println!("  {} x NPE({},{}) load=({},{})", e.rolls, e.config.0, e.config.1, e.load.0, e.load.1);
    }

    println!("\n== Fig. 6: Γ(5, I, 7) on the 6x3 array ==");
    let node = m.best(5, 7).unwrap();
    println!("execution tree ({} rolls):\n{}", node.total_rolls(), node.render(2));
    let s = m.schedule_layer(Gamma::new(5, 100, 7));
    println!("BFS event sequence (Fig. 6C):");
    for e in &s.events {
        println!("  {} x NPE({},{}) load=({},{})", e.rolls, e.config.0, e.config.1, e.load.0, e.load.1);
    }

    println!("\n== Poker Hands (10:85:50:10) on the 16x8 TCD-NPE, B=10 ==");
    let mut m = MapperTree::new(NpeGeometry::PAPER);
    let b = benchmarks().into_iter().find(|b| b.dataset == "Poker Hands").unwrap();
    let ms = m.schedule_model(&b.topology, 10);
    for (l, layer) in ms.layers.iter().enumerate() {
        println!(
            "layer {l} Γ(B={}, I={}, U={}): {} rolls @ {:.0}% utilization",
            layer.gamma.batches,
            layer.gamma.inputs,
            layer.gamma.neurons,
            layer.total_rolls(),
            layer.utilization() * 100.0
        );
    }
    println!(
        "total {} rolls, {} TCD cycles, mean utilization {:.0}%",
        ms.total_rolls(),
        ms.compute_cycles(true),
        ms.utilization() * 100.0
    );
}
