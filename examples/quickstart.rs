//! Quickstart: build a small quantized MLP, schedule it with Algorithm 1,
//! run it on the TCD-NPE simulator, and compare against a conventional-MAC
//! NPE — the whole public API in ~50 lines.
//!
//! Run: `cargo run --release --example quickstart`

use tcd_npe::dataflow::{DataflowEngine, OsEngine};
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::{MlpTopology, QuantizedMlp};

fn main() {
    // 1. A model: 64 inputs, two hidden layers, 4 outputs (Q7.8 weights).
    let topology = MlpTopology::new(vec![64, 48, 16, 4]);
    let mlp = QuantizedMlp::synthesize(topology, /*seed=*/ 42);
    let inputs = mlp.synth_inputs(/*batches=*/ 8, /*seed=*/ 7);

    // 2. The paper's 16×8 TCD-NPE vs the same NPE with conventional MACs.
    let geom = NpeGeometry::PAPER;
    let tcd = OsEngine::tcd(geom).execute(&mlp, &inputs);
    let conv = OsEngine::conventional(geom).execute(&mlp, &inputs);

    // 3. Same neuron values, different time & energy.
    assert_eq!(tcd.outputs, conv.outputs);
    assert_eq!(tcd.outputs, mlp.forward_batch(&inputs));
    println!("outputs[0] = {:?}", &tcd.outputs[0]);
    println!(
        "TCD-NPE : {:>8} cycles  {:>9.2} us  {:>9.3} uJ",
        tcd.cycles,
        tcd.time_us(),
        tcd.energy_uj()
    );
    println!(
        "conv NPE: {:>8} cycles  {:>9.2} us  {:>9.3} uJ",
        conv.cycles,
        conv.time_us(),
        conv.energy_uj()
    );
    println!(
        "speedup {:.2}x, energy saving {:.0}%",
        conv.time_ns / tcd.time_ns,
        (1.0 - tcd.energy.total_pj() / conv.energy.total_pj()) * 100.0
    );
}
