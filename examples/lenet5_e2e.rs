//! LeNet-5 end-to-end on the TCD-NPE (the conv-subsystem quickstart):
//!
//!   CNN topology → im2col lowering → Algorithm-1 schedules
//!                → cycle-accurate NPE execution
//!                → bit-exact check against the Fix16 reference GEMM path
//!                → TCD-MAC vs conventional-MAC comparison
//!
//! Run: `cargo run --release --example lenet5_e2e [batches]`

use tcd_npe::conv::{im2col_expansion, lower_cnn, CnnEngine, QuantizedCnn};
use tcd_npe::mapper::{MapperTree, NpeGeometry};
use tcd_npe::model::zoo::cnn_benchmark_by_name;

fn main() {
    let batches: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);

    let lenet = cnn_benchmark_by_name("lenet-5").expect("LeNet-5 in the CNN zoo");
    println!(
        "LeNet-5 on the 16x8 TCD-NPE, B={batches}\n  topology: {}\n  {} weights, {} MACs/sample, im2col read amplification {:.1}x\n",
        lenet.topology.display(),
        lenet.topology.n_weights(),
        lenet.topology.macs_per_sample(),
        im2col_expansion(&lenet.topology),
    );

    // 1. Lower conv → pool → dense onto the Γ(B, I, U) abstraction.
    let mut mapper = MapperTree::new(NpeGeometry::PAPER);
    let lowered = lower_cnn(&mut mapper, &lenet.topology, batches);
    println!("Algorithm-1 schedules of the lowered GEMMs:");
    for l in &lowered.layers {
        println!(
            "  {:12} Γ(B={:5}, I={:4}, U={:3}) -> {:4} rolls, {:3.0}% utilization",
            l.label,
            l.gamma.batches,
            l.gamma.inputs,
            l.gamma.neurons,
            l.schedule.total_rolls(),
            l.schedule.utilization() * 100.0,
        );
    }
    println!("  total: {} rolls\n", lowered.total_rolls());

    // 2. Execute on the cycle-accurate NPE and verify bit-exactness.
    let cnn = QuantizedCnn::synthesize(lenet.topology.clone(), 0x1E9E7);
    let inputs = cnn.synth_inputs(batches, 0xDA7A);
    let reference = cnn.forward_batch(&inputs);

    let tcd = CnnEngine::tcd(NpeGeometry::PAPER).execute(&cnn, &inputs);
    assert_eq!(tcd.outputs, reference, "NPE output != Fix16 reference");
    println!(
        "TCD-NPE:      {:>9} cycles  {:>8.1} us  {:>8.2} uJ   (outputs verified bit-exact)",
        tcd.cycles,
        tcd.time_us(),
        tcd.energy_uj()
    );

    // 3. Compare against the conventional-MAC NPE.
    let conv = CnnEngine::conventional(NpeGeometry::PAPER).execute(&cnn, &inputs);
    assert_eq!(conv.outputs, reference);
    println!(
        "conv-MAC NPE: {:>9} cycles  {:>8.1} us  {:>8.2} uJ",
        conv.cycles,
        conv.time_us(),
        conv.energy_uj()
    );
    println!(
        "\nTCD speedup {:.2}x, energy {:.2}x",
        conv.time_ns / tcd.time_ns,
        conv.energy.total_pj() / tcd.energy.total_pj()
    );
}
