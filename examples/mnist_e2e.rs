//! End-to-end driver (DESIGN.md §E2E): serve batched inference requests
//! for the MNIST benchmark (784:700:10) through the full stack —
//!
//!   request → coordinator (router + dynamic batcher)
//!           → Algorithm-1 mapper → cycle-accurate TCD-NPE simulator
//!           → PJRT cross-execution of the JAX/Pallas-lowered artifact
//!           → verified response
//!
//! and report latency/throughput/energy. Requires `make artifacts`.
//!
//! Run: `cargo run --release --example mnist_e2e [requests]`

use std::time::{Duration, Instant};
use tcd_npe::coordinator::{BatcherConfig, PjrtSpec};
use tcd_npe::mapper::NpeGeometry;
use tcd_npe::model::QuantizedMlp;
use tcd_npe::runtime::ArtifactManifest;
use tcd_npe::serve::NpeService;

fn main() {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(64);

    let manifest = ArtifactManifest::load("artifacts")
        .expect("artifacts/ missing — run `make artifacts` first");
    let entry = manifest
        .entries
        .iter()
        .find(|e| e.name.starts_with("mnist"))
        .expect("mnist artifact");
    println!(
        "MNIST e2e: {} requests, artifact {} (batch {}), topology {}",
        requests,
        entry.name,
        entry.batch,
        entry.topology.display()
    );

    let mlp = QuantizedMlp::synthesize(entry.topology.clone(), entry.seed);
    let service = NpeService::builder(mlp.clone())
        .geometry(NpeGeometry::PAPER)
        .batcher(BatcherConfig::new(entry.batch, Duration::from_millis(2)))
        .pjrt(PjrtSpec {
            artifact_dir: "artifacts".into(),
            artifact: entry.name.clone(),
        })
        .build()
        .expect("valid serving config");

    // Synthetic MNIST-like digits (deterministic).
    let inputs = mlp.synth_inputs(requests, 0xD161_7);
    let t0 = Instant::now();
    let tickets: Vec<_> = inputs
        .iter()
        .map(|x| service.submit(x.clone()).expect("admitted"))
        .collect();

    let mut verified = 0usize;
    let mut wall_max = Duration::ZERO;
    let mut sim_ns_total = 0.0;
    let mut energy_pj = 0.0;
    let mut class_histogram = [0usize; 10];
    for t in tickets {
        let resp = t.wait_timeout(Duration::from_secs(300)).expect("response");
        verified += resp.verified as usize;
        wall_max = wall_max.max(resp.wall);
        sim_ns_total += resp.npe_time_ns / entry.batch as f64;
        energy_pj += resp.npe_energy_pj;
        // argmax over the 10 output neurons = the predicted digit.
        let pred = resp
            .output
            .iter()
            .enumerate()
            .max_by_key(|(_, v)| **v)
            .map(|(i, _)| i)
            .unwrap();
        class_histogram[pred] += 1;
    }
    let elapsed = t0.elapsed();

    println!("\nserved {requests} requests in {elapsed:?} (host wall-clock)");
    println!("PJRT-verified responses: {verified}/{requests}");
    println!("predicted-class histogram: {class_histogram:?}");
    println!(
        "simulated NPE: {:.1} us/request, {:.0} req/s, {:.2} uJ/request",
        sim_ns_total / requests as f64 / 1e3,
        requests as f64 / (sim_ns_total / 1e9),
        energy_pj / requests as f64 / 1e6
    );
    println!("service: {}", service.metrics().render());
    service.shutdown().expect("clean shutdown");
    assert_eq!(verified, requests, "every batch must be PJRT-verified");
    println!("\nE2E OK — all responses cross-verified against the XLA path");
}
