"""Repo-root pytest hook: make `pytest python/tests/` work from the root
(the build-time package lives under python/)."""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).parent / "python"))
